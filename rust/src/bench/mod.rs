//! Micro/throughput benchmark harness.
//!
//! The offline environment ships no `criterion`, so the `cargo bench`
//! targets (`rust/benches/*.rs`, `harness = false`) use this hand-rolled
//! harness: warmup, fixed-duration sampling, and mean/p50/p95/p99 stats
//! with outlier-robust reporting. It intentionally mimics the parts of
//! criterion the project needs and nothing more.

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th percentile.
    pub p95: Duration,
    /// 99th percentile.
    pub p99: Duration,
    /// Min / max.
    pub min: Duration,
    /// Max.
    pub max: Duration,
}

impl BenchStats {
    /// Single-line report in the style of `criterion`'s summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p95),
            fmt_duration(self.p99),
        )
    }
}

/// Format a duration with adaptive units.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with warmup and a sampling budget.
pub struct Bencher {
    /// Warmup duration before sampling starts.
    pub warmup: Duration,
    /// Total sampling budget.
    pub budget: Duration,
    /// Upper bound on timed iterations (for slow end-to-end benches).
    pub max_iters: usize,
    /// Lower bound on timed iterations.
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000_000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end simulations.
    pub fn end_to_end() -> Self {
        Bencher {
            warmup: Duration::ZERO,
            budget: Duration::from_secs(1),
            max_iters: 5,
            min_iters: 1,
        }
    }

    /// Time `f`, which must consume/produce enough to avoid being
    /// optimized away (use [`std::hint::black_box`] inside).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Sampling.
        let mut samples: Vec<Duration> = Vec::new();
        let s0 = Instant::now();
        while (s0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((iters as f64 - 1.0) * p) as usize];
        BenchStats {
            name: name.to_string(),
            iters,
            mean: total / iters as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[iters - 1],
        }
    }
}

/// Shared command-line surface of the `harness = false` bench binaries:
/// `--smoke` (reduced deterministic run), `--json <path>` (write a
/// `report::RunReport`), `--seeds <n>` (explicit seed-count override).
///
/// Construct with [`BenchOpts::from_env_args`]; the stray `--bench`
/// token some cargo versions forward to bench executables is ignored.
#[derive(Clone, Debug, Default)]
pub struct BenchOpts {
    /// Reduced deterministic CI mode: fewer seeds, smaller instances,
    /// wall-clock timings excluded from the report.
    pub smoke: bool,
    /// Where to write the `BENCH_*.json` report, if anywhere.
    pub json: Option<String>,
    /// `--seeds` override (takes precedence over env defaults).
    pub seeds_override: Option<u64>,
}

impl BenchOpts {
    /// Parse from the process arguments; exits with a usage message on
    /// malformed input (these are terminal binaries, not a library path).
    pub fn from_env_args() -> BenchOpts {
        let tokens = std::env::args().skip(1).filter(|t| t != "--bench");
        match Self::from_tokens(tokens) {
            Ok(opts) => opts,
            Err(e) => {
                eprintln!("error: {e}\nusage: <bench> [--smoke] [--json PATH] [--seeds N]");
                std::process::exit(2);
            }
        }
    }

    /// Parse from explicit tokens (testable core of [`Self::from_env_args`]).
    pub fn from_tokens(tokens: impl IntoIterator<Item = String>) -> Result<BenchOpts, String> {
        let args = crate::cli::Args::parse(tokens)?;
        // The Args grammar degrades a valueless `--json` to a bare flag
        // and binds `--smoke azure` as an option — both would silently run
        // the wrong mode, so the whole vocabulary is checked strictly.
        for key in args.options.keys() {
            match key.as_str() {
                "json" | "seeds" => {}
                "smoke" => return Err("--smoke takes no value".into()),
                other => return Err(format!("unknown option --{other}")),
            }
        }
        for flag in &args.flags {
            match flag.as_str() {
                "smoke" => {}
                "json" | "seeds" => return Err(format!("--{flag} requires a value")),
                other => return Err(format!("unknown flag --{other}")),
            }
        }
        // Args::parse files the first bare token under `command` and the
        // rest under `positionals`; benches take none.
        if let Some(stray) = args.command.as_ref().or_else(|| args.positionals.first()) {
            return Err(format!("unexpected positional argument {stray:?}"));
        }
        Ok(BenchOpts {
            smoke: args.has_flag("smoke"),
            json: args.get("json").map(str::to_string),
            seeds_override: match args.get("seeds") {
                Some(raw) => Some(raw.parse().map_err(|e| format!("--seeds {raw:?}: {e}"))?),
                None => None,
            },
        })
    }

    /// Seed count for a figure sweep: an explicit `--seeds` wins; smoke
    /// mode then pins the smoke default and **ignores** the bench's env
    /// knob (e.g. `MMGPEI_SEEDS`) — the CI preset must be identical on
    /// every machine or locally-refreshed baselines would never match CI;
    /// full runs honor the env knob, then the full default.
    pub fn seeds(&self, env_key: &str, full: u64, smoke: u64) -> u64 {
        let env = std::env::var(env_key).ok().and_then(|v| v.parse().ok());
        self.seeds_from(env, full, smoke)
    }

    /// Pure precedence core of [`Self::seeds`] (testable without touching
    /// the process environment): `--seeds` > smoke preset > env knob > full.
    fn seeds_from(&self, env_override: Option<u64>, full: u64, smoke: u64) -> u64 {
        if let Some(s) = self.seeds_override {
            return s;
        }
        if self.smoke {
            return smoke;
        }
        env_override.unwrap_or(full)
    }

    /// Worker-pool width for this bench run: `MMGPEI_THREADS` wins, else
    /// 1 in smoke mode (the CI preset) or the machine's parallelism
    /// (capped) for full runs. Unlike `MMGPEI_SEEDS`, the env knob *is*
    /// honored in smoke mode — thread count cannot change any report
    /// byte (the pool's determinism contract, which CI enforces by
    /// `cmp`-ing `MMGPEI_THREADS=1` vs `=4` smoke reports).
    pub fn threads(&self) -> usize {
        crate::pool::resolve_threads(self.smoke)
    }

    /// Write `report` to `--json` if requested (no-op otherwise).
    pub fn finish(&self, report: &crate::report::RunReport) {
        if let Some(path) = &self.json {
            report.write(path).unwrap_or_else(|e| panic!("writing report {path}: {e}"));
            eprintln!("wrote {path}");
        }
    }
}

/// A simple markdown/ASCII table builder used by bench binaries to print
/// figure-shaped outputs (rows = series the paper plots).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column names.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a github-markdown table.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_produces_ordered_stats() {
        let b = Bencher {
            warmup: Duration::ZERO,
            budget: Duration::from_millis(30),
            max_iters: 1000,
            min_iters: 5,
        };
        let stats = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..500 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(stats.iters >= 5);
        assert!(stats.min <= stats.p50);
        assert!(stats.p50 <= stats.p95);
        assert!(stats.p95 <= stats.p99);
        assert!(stats.p99 <= stats.max);
        assert!(!stats.report().is_empty());
    }

    #[test]
    fn min_iters_enforced_for_slow_fns() {
        let b = Bencher {
            warmup: Duration::ZERO,
            budget: Duration::from_millis(1),
            max_iters: 100,
            min_iters: 3,
        };
        let stats = b.run("sleepy", || std::thread::sleep(Duration::from_millis(2)));
        assert!(stats.iters >= 3);
    }

    #[test]
    fn duration_formatting_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }

    #[test]
    fn bench_opts_parse() {
        let toks = |s: &str| s.split_whitespace().map(str::to_string).collect::<Vec<_>>();
        let o = BenchOpts::from_tokens(toks("--smoke --json reports/BENCH_fig2.json --seeds 3")).unwrap();
        assert!(o.smoke);
        assert_eq!(o.json.as_deref(), Some("reports/BENCH_fig2.json"));
        assert_eq!(o.seeds_override, Some(3));
        assert_eq!(o.seeds("MMGPEI_NO_SUCH_ENV", 10, 2), 3);
        let d = BenchOpts::from_tokens(toks("")).unwrap();
        assert!(!d.smoke && d.json.is_none());
        assert_eq!(d.seeds("MMGPEI_NO_SUCH_ENV", 10, 2), 10);
        let s = BenchOpts::from_tokens(toks("--smoke")).unwrap();
        assert_eq!(s.seeds("MMGPEI_NO_SUCH_ENV", 10, 2), 2);
        assert!(BenchOpts::from_tokens(toks("--seeds nope")).is_err());
        assert!(BenchOpts::from_tokens(toks("--json --smoke")).is_err(), "valueless --json must not silently no-op");
        assert!(BenchOpts::from_tokens(toks("--smoke --seeds")).is_err());
        assert!(BenchOpts::from_tokens(toks("stray")).is_err());
        assert!(BenchOpts::from_tokens(toks("--smoke stray extra")).is_err(), "--smoke must not swallow a token");
        assert!(BenchOpts::from_tokens(toks("--jsn out.json")).is_err(), "typoed keys must not be dropped");
        assert!(BenchOpts::from_tokens(toks("--verbose")).is_err());
    }

    #[test]
    fn smoke_mode_ignores_env_seed_knob() {
        // Exercises the pure precedence core — no set_var (racy under
        // cargo test's parallel threads).
        let toks = |s: &str| s.split_whitespace().map(str::to_string).collect::<Vec<_>>();
        let smoke = BenchOpts::from_tokens(toks("--smoke")).unwrap();
        assert_eq!(smoke.seeds_from(Some(99), 10, 2), 2, "smoke must pin the CI preset over the env knob");
        let full = BenchOpts::from_tokens(toks("")).unwrap();
        assert_eq!(full.seeds_from(Some(99), 10, 2), 99, "full runs honor the env knob");
        assert_eq!(full.seeds_from(None, 10, 2), 10);
        let explicit = BenchOpts::from_tokens(toks("--smoke --seeds 5")).unwrap();
        assert_eq!(explicit.seeds_from(Some(99), 10, 2), 5, "--seeds beats everything");
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["devices", "time"]);
        t.row(vec!["1".into(), "10.0".into()]);
        t.row(vec!["2".into(), "5.2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| devices | time |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
