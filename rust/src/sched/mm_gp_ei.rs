//! The paper's Algorithm 1: **MM-GP-EI** (GP-EI-MDMT in the experiments).

use super::{DeviceView, EiBackend, Incumbents, NativeBackend, Policy, SchedContext, ScoreMode};
use crate::problem::{ArmId, CostModel, Problem, UserId};

/// Multi-device, multi-tenant GP-EI.
///
/// One shared GP over the full arm set; every time a device frees, the
/// policy refreshes per-user incumbents and dispatches
/// `argmax_{x ∉ 𝓛_ob ∪ running} EIrate_t(x)` (Algorithm 1, line 8).
///
/// Variants, selected by [`ScoreMode`]:
/// * [`MmGpEi::cost_insensitive`] — ablation A1: rank by plain summed EI
///   (Eq. 4) instead of EIrate (Eq. 5), i.e. drop time sensitivity;
/// * [`MmGpEi::device_aware`] / [`MmGpEi::with_cost_model`] — rank by
///   `EI/(c(x, class_d)/s_d)` for the asking device, the first policy
///   whose `device_{joined,left}` hooks do real work.
pub struct MmGpEi {
    backend: Box<dyn EiBackend>,
    incumbents: Incumbents,
    mode: ScoreMode,
    name: String,
    /// Reusable incumbent-vector buffer (zero-allocation select path).
    best_buf: Vec<f64>,
    /// Tenant churn: active-user mask (all true in the static setting).
    /// A departed tenant's incumbent stays dropped even if one of its
    /// in-flight arms completes after the leave — matching the
    /// from-scratch rebuild oracle, which replays history and then
    /// re-clears absent tenants.
    active_users: Vec<bool>,
}

impl MmGpEi {
    /// Standard construction with the native rust GP backend
    /// (device-blind EIrate, [`ScoreMode::CostRate`]).
    pub fn new(problem: &Problem) -> Self {
        Self::with_backend(problem, Box::new(NativeBackend::new(problem)))
    }

    /// Construction with an explicit scoring backend (e.g. the AOT XLA
    /// artifact via [`crate::runtime::XlaBackend`]).
    pub fn with_backend(problem: &Problem, backend: Box<dyn EiBackend>) -> Self {
        let name = format!("GP-EI-MDMT[{}]", backend.label());
        MmGpEi {
            backend,
            incumbents: Incumbents::new(problem.n_users),
            mode: ScoreMode::CostRate,
            name,
            best_buf: Vec::with_capacity(problem.n_users),
            active_users: vec![true; problem.n_users],
        }
    }

    /// Construction over the sharded block-Kronecker GP store
    /// (`[gp] structure = "sharded"`): same [`ScoreMode::CostRate`]
    /// scoring as [`MmGpEi::new`], but the posterior is served by
    /// [`crate::gp::ShardedGp`] — per-tenant Cholesky shards plus a
    /// low-rank cross-tenant coupling — instead of one dense factor.
    /// The policy reports as `GP-EI-MDMT[sharded]`; the dense path
    /// remains the default and the parity oracle.
    pub fn sharded(problem: &Problem, prior: crate::gp::KroneckerPrior) -> Self {
        Self::with_backend(problem, Box::new(NativeBackend::sharded(problem, prior)))
    }

    /// Ablation: cost-insensitive variant ranking by summed EI only.
    pub fn cost_insensitive(problem: &Problem) -> Self {
        let mut p = Self::new(problem);
        p.mode = ScoreMode::EiOnly;
        p.name = "GP-EI-MDMT[no-cost]".into();
        p
    }

    /// Device-aware variant over the uniform cost table: rank by
    /// `EI/(c(x)/s_d)` for the asking device. On a uniform unit-speed
    /// fleet this degenerates bitwise to [`MmGpEi::new`] (`x/1.0` is an
    /// IEEE identity) — pinned by the fleet byte-parity gates.
    pub fn device_aware(problem: &Problem) -> Self {
        let mut p = Self::new(problem);
        p.mode = ScoreMode::DeviceRate;
        p.name = "GP-EI-MDMT[device]".into();
        p
    }

    /// Device-aware variant over a per-(arm, device-class)
    /// [`CostModel`]: rank by `EI/(c(x, class_d)/s_d)`; arms infeasible
    /// on the asking device's class (memory limit) are non-candidates
    /// there. The model's table is copied into the backend, so the
    /// policy stays `'static`.
    pub fn with_cost_model(problem: &Problem, model: &dyn CostModel) -> Self {
        let backend = Box::new(NativeBackend::with_cost_model(problem, model));
        let mut p = Self::with_backend(problem, backend);
        p.mode = ScoreMode::DeviceRate;
        p.name = "GP-EI-MDMT[device]".into();
        p
    }

    /// Current incumbent snapshot (diagnostics/tests).
    pub fn incumbents(&self) -> &Incumbents {
        &self.incumbents
    }

    /// Refresh the reusable incumbent vector `best[u] = z(x_u*(t))` the
    /// backend scores against (no allocation after construction).
    fn fill_best(&mut self, problem: &Problem) {
        self.best_buf.clear();
        let incumbents = &self.incumbents;
        self.best_buf.extend((0..problem.n_users).map(|u| incumbents.value(u)));
    }

    /// Current EIrate scores for all arms (−∞ for selected arms), as the
    /// asking device in `ctx` sees them. Exposed for tests and for the
    /// live coordinator's metrics endpoint. (Copies the backend's score
    /// buffer; the hot path in [`Policy::select`] reads the backend's
    /// argmax index instead.)
    pub fn scores(&mut self, ctx: &SchedContext) -> Vec<f64> {
        self.fill_best(ctx.problem);
        self.backend.eirate(&self.best_buf, ctx.selected, self.mode, ctx.device).to_vec()
    }
}

impl Policy for MmGpEi {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        self.fill_best(ctx.problem);
        // Tournament-tree argmax on the native backend (O(dirty·log |𝓛|)
        // scoring/repair work plus a linear mask byte-diff — see the
        // `sched::backend` module docs); the trait's default linear scan
        // elsewhere. Both skip dispatched arms regardless of the
        // backend's mask convention (native −∞, the XLA artifact −1e30).
        self.backend.select_arm(&self.best_buf, ctx.selected, self.mode, ctx.device)
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.backend.observe(arm, z);
        // Fold the observation into every *active* owner's incumbent. In
        // the static setting every user is active, so this is exactly
        // `update_arm`; under churn a departed tenant's incumbent stays
        // dropped (a rejoin restores it from the finished arms).
        for &u in &problem.arm_users[arm] {
            if self.active_users[u] {
                self.incumbents.update(u, z);
            }
        }
    }

    /// Incremental tenant join: the backend re-enables the tenant's arms
    /// (bit-exact GP catch-up + dirty marking), and the incumbent is
    /// restored from the tenant's already-finished arms — so a
    /// leave-then-rejoin makes decisions bit-identical to a from-scratch
    /// rebuild that replayed the whole observation history (the churn
    /// parity gates pin this).
    fn user_joined(&mut self, problem: &Problem, user: UserId) -> bool {
        if !self.backend.user_joined(problem, user) {
            return false;
        }
        self.active_users[user] = true;
        self.incumbents.clear(user);
        for &a in &problem.user_arms[user] {
            if let Some(z) = self.backend.observed_value(a) {
                self.incumbents.update(user, z);
            }
        }
        true
    }

    /// Incremental tenant leave: freeze the backend's per-arm GP work
    /// for the departed tenant and drop its incumbent (its arms are
    /// masked out of scoring by the driver, so the stale bar can never
    /// influence another tenant's decision).
    fn user_left(&mut self, problem: &Problem, user: UserId) -> bool {
        if !self.backend.user_left(problem, user) {
            return false;
        }
        self.active_users[user] = false;
        self.incumbents.clear(user);
        true
    }

    /// Device fleet churn, delegated to the backend: the shared
    /// posterior and incumbents never see devices, but a
    /// [`ScoreMode::DeviceRate`] backend keys its assembled score
    /// buffer/tournament tree on the asking device and must drop that
    /// cache when the fleet changes (bit-identical on reassembly, so
    /// the in-place path still matches the rebuild oracle — the fleet
    /// parity gates pin this).
    fn device_joined(&mut self, _problem: &Problem, device: usize) -> bool {
        self.backend.device_joined(device)
    }

    /// See [`MmGpEi::device_joined`]: same delegation on a device leave.
    fn device_left(&mut self, _problem: &Problem, device: usize) -> bool {
        self.backend.device_left(device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::problem::PerClassCost;

    /// 2 users × 2 arms each, independent prior, distinct costs.
    fn problem() -> Problem {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        Problem {
            name: "mm".into(),
            n_users: 2,
            cost: vec![1.0, 1.0, 1.0, 10.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: Mat::eye(4),
        }
    }

    fn ctx<'a>(p: &'a Problem, selected: &'a [bool], observed: &'a [bool]) -> SchedContext<'a> {
        ctx_on(p, selected, observed, DeviceView::unit(0))
    }

    fn ctx_on<'a>(
        p: &'a Problem,
        selected: &'a [bool],
        observed: &'a [bool],
        device: DeviceView,
    ) -> SchedContext<'a> {
        SchedContext { problem: p, selected, observed, now: 0.0, device }
    }

    #[test]
    fn selects_unselected_argmax() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        // All arms identical except arm 3 is 10× slower → EIrate lowest.
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let pick = pol.select(&ctx(&p, &selected, &observed)).unwrap();
        assert_ne!(pick, 3, "slow arm must not win EIrate with equal EI");
    }

    #[test]
    fn never_picks_selected_arm() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![true, true, false, true];
        let observed = vec![true, false, false, false];
        assert_eq!(pol.select(&ctx(&p, &selected, &observed)), Some(2));
    }

    #[test]
    fn returns_none_when_exhausted() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![true; 4];
        let observed = vec![true; 4];
        assert_eq!(pol.select(&ctx(&p, &selected, &observed)), None);
    }

    #[test]
    fn cost_insensitive_ignores_cost() {
        let p = problem();
        let mut pol = MmGpEi::cost_insensitive(&p);
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let scores = pol.scores(&ctx(&p, &selected, &observed));
        // Equal prior + equal incumbents → equal EI regardless of cost.
        assert!((scores[0] - scores[3]).abs() < 1e-12);
    }

    #[test]
    fn incumbent_raises_bar() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let before = pol.scores(&ctx(&p, &selected, &observed));
        pol.observe(&p, 0, 0.95);
        let selected = vec![true, false, false, false];
        let observed = vec![true, false, false, false];
        let after = pol.scores(&ctx(&p, &selected, &observed));
        // User 0's remaining arm (1) now competes against incumbent 0.95;
        // user 1's arms keep the empty-incumbent bar → arm 2 should
        // outrank arm 1.
        assert!(after[2] > after[1], "user with worse incumbent gets priority");
        assert!(after[1] < before[1]);
    }

    #[test]
    fn device_aware_unit_device_matches_blind_bitwise() {
        // The degeneration identity behind the fleet byte-parity gates.
        let p = problem();
        let mut aware = MmGpEi::device_aware(&p);
        let mut blind = MmGpEi::new(&p);
        aware.observe(&p, 0, 0.6);
        blind.observe(&p, 0, 0.6);
        let selected = vec![true, false, false, false];
        let observed = vec![true, false, false, false];
        let a = aware.scores(&ctx(&p, &selected, &observed));
        let b = blind.scores(&ctx(&p, &selected, &observed));
        for x in 0..4 {
            assert_eq!(a[x].to_bits(), b[x].to_bits(), "arm {x}");
        }
        assert_eq!(
            aware.select(&ctx(&p, &selected, &observed)),
            blind.select(&ctx(&p, &selected, &observed))
        );
    }

    #[test]
    fn device_aware_skips_infeasible_arm_for_small_class() {
        let p = problem();
        // Class 1 devices can't hold arm 3 (base cost 10 > limit 5).
        let model = PerClassCost::from_problem(&p, vec![1.0, 1.0], vec![f64::INFINITY, 5.0]);
        let mut pol = MmGpEi::with_cost_model(&p, &model);
        let selected = vec![true, true, true, false];
        let observed = vec![true, true, true, false];
        let small = DeviceView { id: 1, speed: 1.0, class: 1 };
        assert_eq!(pol.select(&ctx_on(&p, &selected, &observed, small)), None);
        let big = DeviceView { id: 0, speed: 1.0, class: 0 };
        assert_eq!(pol.select(&ctx_on(&p, &selected, &observed, big)), Some(3));
    }

    #[test]
    fn device_hooks_report_in_place() {
        let p = problem();
        let mut pol = MmGpEi::device_aware(&p);
        assert!(pol.device_joined(&p, 1));
        assert!(pol.device_left(&p, 1));
    }

    #[test]
    fn name_reflects_variant() {
        let p = problem();
        assert_eq!(MmGpEi::new(&p).name(), "GP-EI-MDMT[native]");
        assert_eq!(MmGpEi::cost_insensitive(&p).name(), "GP-EI-MDMT[no-cost]");
        assert_eq!(MmGpEi::device_aware(&p).name(), "GP-EI-MDMT[device]");
    }
}
