//! The paper's Algorithm 1: **MM-GP-EI** (GP-EI-MDMT in the experiments).

use super::{EiBackend, Incumbents, NativeBackend, Policy, SchedContext};
use crate::problem::{ArmId, Problem, UserId};

/// Multi-device, multi-tenant GP-EI.
///
/// One shared GP over the full arm set; every time a device frees, the
/// policy refreshes per-user incumbents and dispatches
/// `argmax_{x ∉ 𝓛_ob ∪ running} EIrate_t(x)` (Algorithm 1, line 8).
///
/// Flags:
/// * `use_cost = false` — ablation A1: rank by plain summed EI (Eq. 4)
///   instead of EIrate (Eq. 5), i.e. drop the paper's time sensitivity.
pub struct MmGpEi {
    backend: Box<dyn EiBackend>,
    incumbents: Incumbents,
    use_cost: bool,
    name: String,
    /// Reusable incumbent-vector buffer (zero-allocation select path).
    best_buf: Vec<f64>,
    /// Tenant churn: active-user mask (all true in the static setting).
    /// A departed tenant's incumbent stays dropped even if one of its
    /// in-flight arms completes after the leave — matching the
    /// from-scratch rebuild oracle, which replays history and then
    /// re-clears absent tenants.
    active_users: Vec<bool>,
}

impl MmGpEi {
    /// Standard construction with the native rust GP backend.
    pub fn new(problem: &Problem) -> Self {
        Self::with_backend(problem, Box::new(NativeBackend::new(problem)))
    }

    /// Construction with an explicit scoring backend (e.g. the AOT XLA
    /// artifact via [`crate::runtime::XlaBackend`]).
    pub fn with_backend(problem: &Problem, backend: Box<dyn EiBackend>) -> Self {
        let name = format!("GP-EI-MDMT[{}]", backend.label());
        MmGpEi {
            backend,
            incumbents: Incumbents::new(problem.n_users),
            use_cost: true,
            name,
            best_buf: Vec::with_capacity(problem.n_users),
            active_users: vec![true; problem.n_users],
        }
    }

    /// Ablation: cost-insensitive variant ranking by summed EI only.
    pub fn cost_insensitive(problem: &Problem) -> Self {
        let mut p = Self::new(problem);
        p.use_cost = false;
        p.name = "GP-EI-MDMT[no-cost]".into();
        p
    }

    /// Current incumbent snapshot (diagnostics/tests).
    pub fn incumbents(&self) -> &Incumbents {
        &self.incumbents
    }

    /// Refresh the reusable incumbent vector `best[u] = z(x_u*(t))` the
    /// backend scores against (no allocation after construction).
    fn fill_best(&mut self, problem: &Problem) {
        self.best_buf.clear();
        let incumbents = &self.incumbents;
        self.best_buf.extend((0..problem.n_users).map(|u| incumbents.value(u)));
    }

    /// Current EIrate scores for all arms (−∞ for selected arms).
    /// Exposed for tests and for the live coordinator's metrics endpoint.
    /// (Copies the backend's score buffer; the hot path in
    /// [`Policy::select`] reads the backend's argmax index instead.)
    pub fn scores(&mut self, ctx: &SchedContext) -> Vec<f64> {
        self.fill_best(ctx.problem);
        self.backend.eirate(&self.best_buf, ctx.selected, self.use_cost).to_vec()
    }
}

impl Policy for MmGpEi {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        self.fill_best(ctx.problem);
        // Tournament-tree argmax on the native backend (O(dirty·log |𝓛|)
        // scoring/repair work plus a linear mask byte-diff — see the
        // `sched::backend` module docs); the trait's default linear scan
        // elsewhere. Both skip dispatched arms regardless of the
        // backend's mask convention (native −∞, the XLA artifact −1e30).
        self.backend.select_arm(&self.best_buf, ctx.selected, self.use_cost)
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.backend.observe(arm, z);
        // Fold the observation into every *active* owner's incumbent. In
        // the static setting every user is active, so this is exactly
        // `update_arm`; under churn a departed tenant's incumbent stays
        // dropped (a rejoin restores it from the finished arms).
        for &u in &problem.arm_users[arm] {
            if self.active_users[u] {
                self.incumbents.update(u, z);
            }
        }
    }

    /// Incremental tenant join: the backend re-enables the tenant's arms
    /// (bit-exact GP catch-up + dirty marking), and the incumbent is
    /// restored from the tenant's already-finished arms — so a
    /// leave-then-rejoin makes decisions bit-identical to a from-scratch
    /// rebuild that replayed the whole observation history (the churn
    /// parity gates pin this).
    fn user_joined(&mut self, problem: &Problem, user: UserId) -> bool {
        if !self.backend.user_joined(problem, user) {
            return false;
        }
        self.active_users[user] = true;
        self.incumbents.clear(user);
        for &a in &problem.user_arms[user] {
            if let Some(z) = self.backend.observed_value(a) {
                self.incumbents.update(user, z);
            }
        }
        true
    }

    /// Incremental tenant leave: freeze the backend's per-arm GP work
    /// for the departed tenant and drop its incumbent (its arms are
    /// masked out of scoring by the driver, so the stale bar can never
    /// influence another tenant's decision).
    fn user_left(&mut self, problem: &Problem, user: UserId) -> bool {
        if !self.backend.user_left(problem, user) {
            return false;
        }
        self.active_users[user] = false;
        self.incumbents.clear(user);
        true
    }

    /// Device fleet churn is a no-op for MM-GP-EI: the shared posterior,
    /// incumbents, and EIrate scores are functions of the *arm* history
    /// only — which devices are online never enters Eqs. 4–5 — so the
    /// in-place "change" is trivially bit-identical to the from-scratch
    /// rebuild oracle (the fleet parity gates pin this).
    fn device_joined(&mut self, _problem: &Problem, _device: usize) -> bool {
        true
    }

    /// See `device_joined` above: same no-op contract on a device
    /// leave.
    fn device_left(&mut self, _problem: &Problem, _device: usize) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    /// 2 users × 2 arms each, independent prior, distinct costs.
    fn problem() -> Problem {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        Problem {
            name: "mm".into(),
            n_users: 2,
            cost: vec![1.0, 1.0, 1.0, 10.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 4],
            prior_cov: Mat::eye(4),
        }
    }

    fn ctx<'a>(p: &'a Problem, selected: &'a [bool], observed: &'a [bool]) -> SchedContext<'a> {
        SchedContext { problem: p, selected, observed, now: 0.0 }
    }

    #[test]
    fn selects_unselected_argmax() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        // All arms identical except arm 3 is 10× slower → EIrate lowest.
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let pick = pol.select(&ctx(&p, &selected, &observed)).unwrap();
        assert_ne!(pick, 3, "slow arm must not win EIrate with equal EI");
    }

    #[test]
    fn never_picks_selected_arm() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![true, true, false, true];
        let observed = vec![true, false, false, false];
        assert_eq!(pol.select(&ctx(&p, &selected, &observed)), Some(2));
    }

    #[test]
    fn returns_none_when_exhausted() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![true; 4];
        let observed = vec![true; 4];
        assert_eq!(pol.select(&ctx(&p, &selected, &observed)), None);
    }

    #[test]
    fn cost_insensitive_ignores_cost() {
        let p = problem();
        let mut pol = MmGpEi::cost_insensitive(&p);
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let scores = pol.scores(&ctx(&p, &selected, &observed));
        // Equal prior + equal incumbents → equal EI regardless of cost.
        assert!((scores[0] - scores[3]).abs() < 1e-12);
    }

    #[test]
    fn incumbent_raises_bar() {
        let p = problem();
        let mut pol = MmGpEi::new(&p);
        let selected = vec![false; 4];
        let observed = vec![false; 4];
        let before = pol.scores(&ctx(&p, &selected, &observed));
        pol.observe(&p, 0, 0.95);
        let selected = vec![true, false, false, false];
        let observed = vec![true, false, false, false];
        let after = pol.scores(&ctx(&p, &selected, &observed));
        // User 0's remaining arm (1) now competes against incumbent 0.95;
        // user 1's arms keep the empty-incumbent bar → arm 2 should
        // outrank arm 1.
        assert!(after[2] > after[1], "user with worse incumbent gets priority");
        assert!(after[1] < before[1]);
    }

    #[test]
    fn name_reflects_variant() {
        let p = problem();
        assert_eq!(MmGpEi::new(&p).name(), "GP-EI-MDMT[native]");
        assert_eq!(MmGpEi::cost_insensitive(&p).name(), "GP-EI-MDMT[no-cost]");
    }
}
