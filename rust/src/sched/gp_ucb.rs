//! GP-UCB policies — the acquisition family ease.ml (Li et al., 2018)
//! built its single-device multi-tenant scheduler on, implemented here as
//! a comparison family for MM-GP-EI (the paper positions itself against
//! exactly this line of work).
//!
//! UCB value: `ucb_t(x) = μ_t(x) + √β_t · σ_t(x)` with the standard
//! `β_t = 2·log(|𝓛|·t²·π²/6δ)` schedule (Srinivas et al., 2012). The
//! multi-tenant variant ranks arms by the *summed incumbent-clipped* UCB
//! improvement per unit cost — the closest UCB analogue of EIrate — while
//! the per-user variant replicates classic single-tenant GP-UCB under a
//! round-robin allocator.

use super::{EiBackend, Incumbents, NativeBackend, Policy, SchedContext};
use crate::pool::WorkerPool;
use crate::problem::{ArmId, Problem};

/// UCB exploration schedule `√β_t`.
fn sqrt_beta(n_arms: usize, t: usize, delta: f64) -> f64 {
    let t = (t.max(1)) as f64;
    let l = n_arms as f64;
    (2.0 * (l * t * t * std::f64::consts::PI * std::f64::consts::PI / (6.0 * delta)).ln())
        .max(0.0)
        .sqrt()
}

/// **GP-UCB-MDMT**: shared GP, global allocation by summed clipped-UCB
/// improvement rate — the UCB analogue of Algorithm 1, representing the
/// ease.ml lineage in the cross-acquisition benchmark.
pub struct GpUcbMdmt {
    backend: NativeBackend,
    incumbents: Incumbents,
    delta: f64,
    t: usize,
}

impl GpUcbMdmt {
    /// Build with confidence parameter δ (default 0.1).
    pub fn new(problem: &Problem) -> Self {
        GpUcbMdmt {
            backend: NativeBackend::new(problem),
            incumbents: Incumbents::new(problem.n_users),
            delta: 0.1,
            t: 0,
        }
    }
}

impl Policy for GpUcbMdmt {
    fn name(&self) -> String {
        "GP-UCB-MDMT".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        let (mu, sd) = self.backend.posterior();
        let sb = sqrt_beta(ctx.problem.n_arms(), self.t + 1, self.delta);
        let mut best_arm = None;
        let mut best_score = f64::NEG_INFINITY;
        for x in ctx.candidates() {
            let ucb = mu[x] + sb * sd[x];
            // Summed improvement of the optimistic value over each
            // owner's incumbent, per unit cost.
            let mut gain = 0.0;
            for &u in &ctx.problem.arm_users[x] {
                gain += (ucb - self.incumbents.value(u)).max(0.0);
            }
            let score = gain / ctx.problem.cost[x];
            if score > best_score {
                best_score = score;
                best_arm = Some(x);
            }
        }
        best_arm
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.t += 1;
        self.backend.observe(arm, z);
        self.incumbents.update_arm(problem, arm, z);
    }
}

/// **GP-UCB-Round-Robin**: classic per-user single-tenant GP-UCB under a
/// round-robin user allocator (the natural UCB twin of GP-EI-Round-Robin).
pub struct GpUcbRoundRobin {
    /// One shared-prior GP per user restricted to their arms.
    users: Vec<UserUcb>,
    next_user: usize,
    delta: f64,
    t: usize,
    /// Shards the independent per-user GP updates (`MMGPEI_THREADS`).
    pool: WorkerPool,
}

struct UserUcb {
    arms: Vec<ArmId>,
    gp: crate::gp::Gp,
    local: Vec<usize>,
}

impl GpUcbRoundRobin {
    /// Build for a problem instance (pool width from `MMGPEI_THREADS`).
    pub fn new(problem: &Problem) -> Self {
        Self::with_pool(problem, WorkerPool::from_env())
    }

    /// Build with an explicit worker pool for the per-user GP shards.
    pub fn with_pool(problem: &Problem, pool: WorkerPool) -> Self {
        let users = (0..problem.n_users)
            .map(|u| {
                let arms = problem.user_arms[u].clone();
                let mean: Vec<f64> = arms.iter().map(|&a| problem.prior_mean[a]).collect();
                let cov = crate::linalg::principal_submatrix(&problem.prior_cov, &arms);
                let mut local = vec![usize::MAX; problem.n_arms()];
                for (i, &a) in arms.iter().enumerate() {
                    local[a] = i;
                }
                UserUcb { arms, gp: crate::gp::Gp::new(mean, cov), local }
            })
            .collect();
        GpUcbRoundRobin { users, next_user: 0, delta: 0.1, t: 0, pool }
    }
}

impl Policy for GpUcbRoundRobin {
    fn name(&self) -> String {
        "GP-UCB-Round-Robin".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        let n = self.users.len();
        for step in 0..n {
            let u = (self.next_user + step) % n;
            let user = &self.users[u];
            let sb = sqrt_beta(user.arms.len(), self.t + 1, self.delta);
            let mut best = None;
            let mut best_ucb = f64::NEG_INFINITY;
            for (li, &a) in user.arms.iter().enumerate() {
                if ctx.selected[a] {
                    continue;
                }
                let ucb = user.gp.posterior_mean(li) + sb * user.gp.posterior_std(li);
                if ucb > best_ucb {
                    best_ucb = ucb;
                    best = Some(a);
                }
            }
            if best.is_some() {
                self.next_user = (u + 1) % n;
                return best;
            }
        }
        None
    }

    fn observe(&mut self, _problem: &Problem, arm: ArmId, z: f64) {
        self.t += 1;
        self.pool.for_each_chunk_mut(&mut self.users, |chunk| {
            for user in chunk {
                let li = user.local[arm];
                if li != usize::MAX && !user.gp.is_observed(li) {
                    user.gp.observe(li, z);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::DeviceView;
    use crate::sim::{simulate, SimConfig};

    fn test_ctx<'a>(p: &'a Problem, selected: &'a [bool], observed: &'a [bool]) -> SchedContext<'a> {
        SchedContext { problem: p, selected, observed, now: 0.0, device: DeviceView::unit(0) }
    }

    fn problem() -> (Problem, crate::problem::Truth) {
        let user_arms = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let arm_users = Problem::compute_arm_users(6, &user_arms);
        let p = Problem {
            name: "ucb".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 1.5, 1.0, 2.0, 1.5],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 6],
            prior_cov: Mat::eye(6),
        };
        let t = crate::problem::Truth { z: vec![0.4, 0.8, 0.6, 0.7, 0.3, 0.9] };
        (p, t)
    }

    #[test]
    fn sqrt_beta_grows_with_time_and_arms() {
        assert!(sqrt_beta(8, 2, 0.1) > sqrt_beta(8, 1, 0.1));
        assert!(sqrt_beta(64, 5, 0.1) > sqrt_beta(8, 5, 0.1));
        assert!(sqrt_beta(8, 5, 0.01) > sqrt_beta(8, 5, 0.1), "smaller δ explores more");
    }

    #[test]
    fn ucb_mdmt_completes_and_converges() {
        let (p, t) = problem();
        let mut pol = GpUcbMdmt::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 2, ..Default::default() });
        assert_eq!(r.observations.len(), 6);
        assert_eq!(r.inst_regret.final_value(), 0.0);
    }

    #[test]
    fn ucb_round_robin_completes() {
        let (p, t) = problem();
        let mut pol = GpUcbRoundRobin::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 1, ..Default::default() });
        assert_eq!(r.observations.len(), 6);
        assert_eq!(r.inst_regret.final_value(), 0.0);
    }

    #[test]
    fn ucb_mdmt_prefers_uncertain_cheap_arms() {
        let (p, _) = problem();
        let mut pol = GpUcbMdmt::new(&p);
        // Observe arm 1 high → user 0's incumbent rises.
        pol.observe(&p, 1, 0.9);
        let selected = vec![false, true, false, false, false, false];
        let observed = selected.clone();
        let ctx = test_ctx(&p, &selected, &observed);
        let pick = pol.select(&ctx).unwrap();
        // User 1 has incumbent 0 → any of their arms dominates user 0's
        // remaining arms; cheapest user-1 arm (3, cost 1.0) should win.
        assert_eq!(pick, 3, "UCB gain/cost should favour user 1's cheap arm");
    }

    #[test]
    fn ucb_never_selects_selected() {
        let (p, t) = problem();
        let mut pol = GpUcbMdmt::new(&p);
        let mut selected = vec![false; 6];
        let observed = vec![false; 6];
        for _ in 0..6 {
            let a = pol.select(&test_ctx(&p, &selected, &observed)).unwrap();
            assert!(!selected[a]);
            selected[a] = true;
            pol.observe(&p, a, t.z[a]);
        }
        assert!(pol.select(&test_ctx(&p, &selected, &selected)).is_none());
    }
}
