//! Incremental argmax over per-arm scores: a tournament (segment-max)
//! tree.
//!
//! The scheduler's decision rule is `argmax_x EIrate_t(x)`, and after the
//! dirty-set cache of PR 1 only `O(|dirty|)` scores change per decision —
//! but the selection itself still paid a full `O(|𝓛|)` linear scan. The
//! [`TournamentTree`] maintains a binary max-tree over the score vector:
//! updating one leaf repairs its root path in `O(log |𝓛|)`, and the
//! current argmax is an `O(1)` read of the root, so the scoring/repair
//! work per decision drops to `O(|dirty| · log |𝓛|)` (the backend keeps
//! one linear byte-compare of the selected mask — see
//! `sched::backend`).
//!
//! **Determinism contract.** Ties break toward the *lowest index* — the
//! tree's combine step prefers the left child on equality, which is
//! exactly what the linear scan's `score > best` comparison yields — so
//! the tree is bit-for-bit interchangeable with the brute-force scan
//! (property-tested in `rust/tests/properties.rs` and hard-gated against
//! the rescan oracle in `benches/perf_hotpath.rs`). Scores must not be
//! NaN; the scheduler's scores are sums of finite EI values divided by
//! positive costs, with `-∞` as the dispatched-arm mask, so NaN can never
//! reach a leaf.

/// Segment-max tree over a fixed-size score vector with lowest-index
/// tie-breaking. All storage is preallocated at construction; updates and
/// reads never allocate.
#[derive(Clone, Debug)]
pub struct TournamentTree {
    /// Number of real leaves (arms).
    n: usize,
    /// Power-of-two leaf span; leaf `i` lives at node `m + i`.
    m: usize,
    /// Per-node best score (1-based heap layout; `score[1]` is the root).
    score: Vec<f64>,
    /// Per-node argmax leaf index for `score`.
    arg: Vec<u32>,
}

impl TournamentTree {
    /// Tree over `n` leaves, all initialized to `-∞`.
    ///
    /// Padding leaves (indices `n..m`) also hold `-∞`; because ties
    /// prefer the left child, a padding leaf can only surface at the root
    /// when `n == 0`.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "tournament tree index space is u32");
        let m = n.next_power_of_two().max(1);
        let mut arg = vec![0u32; 2 * m];
        // Leaves carry their own index; internal nodes of an all-(−∞)
        // tree resolve to their leftmost leaf.
        for i in 0..m {
            arg[m + i] = i as u32;
        }
        for i in (1..m).rev() {
            arg[i] = arg[2 * i];
        }
        TournamentTree { n, m, score: vec![f64::NEG_INFINITY; 2 * m], arg }
    }

    /// Number of real leaves.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the tree has no leaves.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Recombine internal node `node` from its children. The single copy
    /// of the determinism-critical comparison: **left-preferring**, so
    /// equality keeps the lower leaf index (both the incremental repair
    /// and the bulk rebuild must break ties identically).
    #[inline]
    fn pull_up(&mut self, node: usize) {
        let (l, r) = (2 * node, 2 * node + 1);
        if self.score[l] >= self.score[r] {
            self.score[node] = self.score[l];
            self.arg[node] = self.arg[l];
        } else {
            self.score[node] = self.score[r];
            self.arg[node] = self.arg[r];
        }
    }

    /// Set leaf `i` to `s` and repair the path to the root — `O(log n)`.
    #[inline]
    pub fn update(&mut self, i: usize, s: f64) {
        debug_assert!(i < self.n, "leaf {i} out of range (n = {})", self.n);
        debug_assert!(!s.is_nan(), "tournament scores must not be NaN");
        let mut node = self.m + i;
        self.score[node] = s;
        while node > 1 {
            node /= 2;
            self.pull_up(node);
        }
    }

    /// Bulk-load every leaf from `scores` and rebuild bottom-up — `O(n)`,
    /// the path taken when a [`crate::sched::ScoreMode`] flip (or a new
    /// asking device under `DeviceRate`) invalidates the whole score
    /// vector at once.
    pub fn rebuild_from(&mut self, scores: &[f64]) {
        assert_eq!(scores.len(), self.n, "rebuild size mismatch");
        debug_assert!(scores.iter().all(|s| !s.is_nan()), "tournament scores must not be NaN");
        self.score[self.m..self.m + self.n].copy_from_slice(scores);
        for s in &mut self.score[self.m + self.n..] {
            *s = f64::NEG_INFINITY;
        }
        for node in (1..self.m).rev() {
            self.pull_up(node);
        }
    }

    /// Current `(score, argmax)` — `O(1)`. The argmax is the lowest index
    /// attaining the maximum; when every leaf is `-∞` the score is `-∞`
    /// (callers treat that as "no candidate").
    #[inline]
    pub fn best(&self) -> (f64, usize) {
        // Node 1 is the root (for a 1-leaf tree it is also the leaf).
        (self.score[1], self.arg[1] as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear-scan oracle with the scheduler's exact comparison (`>`,
    /// first maximum wins).
    fn linear_argmax(scores: &[f64]) -> (f64, Option<usize>) {
        let mut best = f64::NEG_INFINITY;
        let mut arg = None;
        for (i, &s) in scores.iter().enumerate() {
            if s > best {
                best = s;
                arg = Some(i);
            }
        }
        (best, arg)
    }

    #[test]
    fn matches_linear_scan_across_sizes() {
        for n in [1usize, 2, 3, 5, 8, 17, 33, 100] {
            let mut tree = TournamentTree::new(n);
            let mut scores = vec![f64::NEG_INFINITY; n];
            assert_eq!(tree.len(), n);
            assert!(!tree.is_empty());
            // Deterministic pseudo-random update sequence with many ties.
            let mut state = 0x9E3779B97F4A7C15u64 ^ n as u64;
            for step in 0..400 {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let i = (state >> 33) as usize % n;
                let s = match state % 5 {
                    0 => f64::NEG_INFINITY,
                    1 => 0.0,
                    2 => ((state >> 7) % 8) as f64 * 0.25,
                    3 => ((state >> 11) % 3) as f64 - 1.0,
                    _ => ((state >> 17) % 1000) as f64 / 64.0,
                };
                scores[i] = s;
                tree.update(i, s);
                let (want_s, want_i) = linear_argmax(&scores);
                let (got_s, got_i) = tree.best();
                assert_eq!(got_s.to_bits(), want_s.to_bits(), "n={n} step={step} score");
                if let Some(wi) = want_i {
                    assert_eq!(got_i, wi, "n={n} step={step} argmax");
                } else {
                    assert_eq!(got_s, f64::NEG_INFINITY, "n={n} step={step} empty");
                }
            }
        }
    }

    #[test]
    fn ties_break_to_lowest_index() {
        let mut tree = TournamentTree::new(6);
        for i in 0..6 {
            tree.update(i, 1.0);
        }
        assert_eq!(tree.best(), (1.0, 0));
        tree.update(0, 0.5);
        assert_eq!(tree.best(), (1.0, 1));
        tree.update(3, 2.0);
        tree.update(5, 2.0);
        assert_eq!(tree.best(), (2.0, 3));
    }

    #[test]
    fn rebuild_matches_incremental_updates() {
        let scores: Vec<f64> = (0..13).map(|i| ((i * 7) % 5) as f64).collect();
        let mut bulk = TournamentTree::new(13);
        bulk.rebuild_from(&scores);
        let mut inc = TournamentTree::new(13);
        for (i, &s) in scores.iter().enumerate() {
            inc.update(i, s);
        }
        assert_eq!(bulk.best(), inc.best());
        assert_eq!(bulk.score, inc.score);
        assert_eq!(bulk.arg, inc.arg);
    }

    #[test]
    fn all_masked_reads_neg_infinity() {
        let mut tree = TournamentTree::new(4);
        for i in 0..4 {
            tree.update(i, f64::NEG_INFINITY);
        }
        let (s, i) = tree.best();
        assert_eq!(s, f64::NEG_INFINITY);
        assert!(i < 4, "argmax stays a real leaf even when all are masked");
    }

    #[test]
    fn single_leaf_tree() {
        let mut tree = TournamentTree::new(1);
        assert_eq!(tree.best().0, f64::NEG_INFINITY);
        tree.update(0, 3.5);
        assert_eq!(tree.best(), (3.5, 0));
    }
}
