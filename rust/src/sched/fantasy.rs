//! Pending-arm fantasizing (constant liar / kriging believer) — an
//! extension the paper leaves on the table.
//!
//! Algorithm 1 conditions the GP only on *finished* observations, so
//! with many devices several in-flight arms can carry stale-high EI and
//! the scheduler dispatches near-duplicates (the effect behind the
//! paper's efficiency decay as M → N in Figure 5). The standard batch-BO
//! remedy (Ginsbourger et al.) conditions the posterior on each pending
//! arm at a *fantasy* value — here its current posterior mean ("kriging
//! believer") — collapsing its σ and suppressing correlated candidates.
//!
//! [`MmGpEiFantasy`] implements MM-GP-EI with kriging-believer pending
//! conditioning; `ablations` benches it against plain MM-GP-EI across
//! device counts (expected: no effect at M = 1, growing benefit as the
//! pending set grows).

use super::{EiBackend, Incumbents, NativeBackend, Policy, SchedContext};
use crate::gp::expected_improvement;
use crate::problem::{ArmId, Problem};

/// MM-GP-EI with kriging-believer conditioning on in-flight arms.
pub struct MmGpEiFantasy {
    backend: NativeBackend,
    incumbents: Incumbents,
}

impl MmGpEiFantasy {
    /// Build for a problem instance.
    pub fn new(problem: &Problem) -> Self {
        MmGpEiFantasy {
            backend: NativeBackend::new(problem),
            incumbents: Incumbents::new(problem.n_users),
        }
    }
}

impl Policy for MmGpEiFantasy {
    fn name(&self) -> String {
        "GP-EI-MDMT[fantasy]".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        // Pending = dispatched but unfinished.
        let pending: Vec<ArmId> = (0..ctx.problem.n_arms())
            .filter(|&x| ctx.selected[x] && !ctx.observed[x])
            .collect();
        // Fantasize: clone the real-observation GP and condition each
        // pending arm at its current posterior mean. O(|pending|·L·t) on
        // top of the clone — an ablation-grade cost, acceptable at the
        // paper's scales.
        let mut gp = self.backend.gp().clone();
        for &x in &pending {
            if !gp.is_observed(x) {
                let mean = gp.posterior_mean(x);
                gp.observe(x, mean);
            }
        }
        let best: Vec<f64> =
            (0..ctx.problem.n_users).map(|u| self.incumbents.value(u)).collect();
        let mut best_arm = None;
        let mut best_score = f64::NEG_INFINITY;
        for x in ctx.candidates() {
            let mu = gp.posterior_mean(x);
            let sigma = gp.posterior_std(x);
            let mut ei_sum = 0.0;
            for &u in &ctx.problem.arm_users[x] {
                ei_sum += expected_improvement(mu, sigma, best[u]);
            }
            let score = ei_sum / ctx.problem.cost[x];
            if score > best_score {
                best_score = score;
                best_arm = Some(x);
            }
        }
        best_arm
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.backend.observe(arm, z);
        self.incumbents.update_arm(problem, arm, z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, Matern52};
    use crate::sched::DeviceView;
    use crate::sim::{simulate, SimConfig};

    fn ctx<'a>(p: &'a Problem, selected: &'a [bool], observed: &'a [bool]) -> SchedContext<'a> {
        SchedContext { problem: p, selected, observed, now: 0.0, device: DeviceView::unit(0) }
    }

    /// One user, correlated arms on a line — fantasy conditioning must
    /// push the second pick away from a pending arm's neighborhood.
    fn correlated_problem() -> (Problem, crate::problem::Truth) {
        let pts: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 * 0.2]).collect();
        let cov = Matern52 { variance: 1.0, lengthscale: 1.0 }.gram(&pts);
        let user_arms = vec![(0..8).collect::<Vec<_>>()];
        let arm_users = Problem::compute_arm_users(8, &user_arms);
        let p = Problem {
            name: "corr".into(),
            n_users: 1,
            cost: vec![1.0; 8],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 8],
            prior_cov: cov,
        };
        let t = crate::problem::Truth {
            z: vec![0.3, 0.4, 0.5, 0.6, 0.7, 0.6, 0.5, 0.4],
        };
        (p, t)
    }

    #[test]
    fn fantasy_diversifies_concurrent_picks() {
        let (p, _) = correlated_problem();
        let mut pol = MmGpEiFantasy::new(&p);
        let observed = vec![false; 8];
        // First pick with nothing pending.
        let mut selected = vec![false; 8];
        let first = pol.select(&ctx(&p, &selected, &observed)).unwrap();
        selected[first] = true;
        // Second pick while the first is pending: must not be adjacent
        // (the fantasy collapses σ in the neighborhood).
        let second = pol.select(&ctx(&p, &selected, &observed)).unwrap();
        let dist = (first as i64 - second as i64).abs();
        assert!(dist >= 2, "fantasy pick {second} too close to pending {first}");
    }

    #[test]
    fn completes_all_arms_under_parallelism() {
        let (p, t) = correlated_problem();
        let mut pol = MmGpEiFantasy::new(&p);
        let r = simulate(&p, &t, &mut pol, &SimConfig { n_devices: 4, ..Default::default() });
        assert_eq!(r.observations.len(), 8);
        assert_eq!(r.inst_regret.final_value(), 0.0);
    }

    #[test]
    fn equals_plain_mdmt_with_single_device() {
        // With M = 1 nothing is ever pending at decision time, so the
        // fantasy variant must make identical decisions to plain MDMT.
        let (p, t) = correlated_problem();
        let cfg = SimConfig { n_devices: 1, ..Default::default() };
        let r_f = {
            let mut pol = MmGpEiFantasy::new(&p);
            simulate(&p, &t, &mut pol, &cfg)
        };
        let r_p = {
            let mut pol = super::super::MmGpEi::new(&p);
            simulate(&p, &t, &mut pol, &cfg)
        };
        let a: Vec<_> = r_f.observations.iter().map(|o| o.arm).collect();
        let b: Vec<_> = r_p.observations.iter().map(|o| o.arm).collect();
        assert_eq!(a, b);
    }
}
