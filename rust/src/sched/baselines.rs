//! Baseline policies from the paper's §6.1 protocol plus reference
//! policies used by the ablation benches.

use super::{Incumbents, Policy, SchedContext};
use crate::gp::Gp;
use crate::linalg::principal_submatrix;
use crate::pool::WorkerPool;
use crate::prng::Rng;
use crate::problem::{ArmId, Problem, Truth, UserId};

/// A single user's private GP-EI instance: GP restricted to that user's
/// candidate set, classic (single-tenant) expected-improvement selection.
struct UserGpEi {
    /// Arms of this user, in local order (local index → global ArmId).
    arms: Vec<ArmId>,
    /// Map global arm → local index (usize::MAX if not owned).
    local: Vec<usize>,
    gp: Gp,
}

impl UserGpEi {
    fn new(problem: &Problem, user: UserId) -> Self {
        let arms = problem.user_arms[user].clone();
        let mean: Vec<f64> = arms.iter().map(|&a| problem.prior_mean[a]).collect();
        let cov = principal_submatrix(&problem.prior_cov, &arms);
        let mut local = vec![usize::MAX; problem.n_arms()];
        for (i, &a) in arms.iter().enumerate() {
            local[a] = i;
        }
        UserGpEi { arms, local, gp: Gp::new(mean, cov) }
    }

    /// Incorporate an observation if this user owns the arm.
    fn observe(&mut self, arm: ArmId, z: f64) {
        let li = self.local[arm];
        if li != usize::MAX && !self.gp.is_observed(li) {
            self.gp.observe(li, z);
        }
    }

    /// Classic GP-EI pick among this user's unselected arms.
    fn select(&self, selected: &[bool], best: f64) -> Option<ArmId> {
        let mut best_arm = None;
        let mut best_ei = f64::NEG_INFINITY;
        for (li, &a) in self.arms.iter().enumerate() {
            if selected[a] {
                continue;
            }
            let ei = self.gp.ei(li, best);
            if ei > best_ei {
                best_ei = ei;
                best_arm = Some(a);
            }
        }
        best_arm
    }

    fn has_candidate(&self, selected: &[bool]) -> bool {
        self.arms.iter().any(|&a| !selected[a])
    }
}

/// Shared plumbing for the "pick a user, then run that user's GP-EI"
/// baselines (GP-EI-Round-Robin and GP-EI-Random of §6.1).
///
/// Per-user GPs are fully independent state (SoA: one `UserGpEi` per
/// tenant), so the per-completion posterior updates shard across the
/// worker pool — each user is touched by exactly one thread and the
/// floats are identical to the serial loop at any `MMGPEI_THREADS`.
struct PerUserGpEi {
    users: Vec<UserGpEi>,
    incumbents: Incumbents,
    pool: WorkerPool,
}

impl PerUserGpEi {
    fn new(problem: &Problem, pool: WorkerPool) -> Self {
        PerUserGpEi {
            users: (0..problem.n_users).map(|u| UserGpEi::new(problem, u)).collect(),
            incumbents: Incumbents::new(problem.n_users),
            pool,
        }
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.pool.for_each_chunk_mut(&mut self.users, |chunk| {
            for user in chunk {
                user.observe(arm, z);
            }
        });
        self.incumbents.update_arm(problem, arm, z);
    }
}

/// **GP-EI-Round-Robin**: each user runs an independent GP-EI; the
/// service serves users cyclically, skipping users with nothing left.
pub struct GpEiRoundRobin {
    inner: PerUserGpEi,
    next_user: usize,
}

impl GpEiRoundRobin {
    /// Build for a problem instance (pool width from `MMGPEI_THREADS`).
    pub fn new(problem: &Problem) -> Self {
        Self::with_pool(problem, WorkerPool::from_env())
    }

    /// Build with an explicit worker pool for the per-user GP shards.
    pub fn with_pool(problem: &Problem, pool: WorkerPool) -> Self {
        GpEiRoundRobin { inner: PerUserGpEi::new(problem, pool), next_user: 0 }
    }
}

impl Policy for GpEiRoundRobin {
    fn name(&self) -> String {
        "GP-EI-Round-Robin".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        let n = self.inner.users.len();
        for step in 0..n {
            let u = (self.next_user + step) % n;
            if self.inner.users[u].has_candidate(ctx.selected) {
                let pick = self.inner.users[u].select(ctx.selected, self.inner.incumbents.value(u));
                self.next_user = (u + 1) % n;
                return pick;
            }
        }
        None
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.inner.observe(problem, arm, z);
    }
}

/// **GP-EI-Random**: each user runs an independent GP-EI; the next user
/// to serve is drawn uniformly among users with remaining candidates.
pub struct GpEiRandom {
    inner: PerUserGpEi,
    rng: Rng,
}

impl GpEiRandom {
    /// Build with an explicit seed (runs are deterministic per seed;
    /// pool width from `MMGPEI_THREADS`).
    pub fn new(problem: &Problem, seed: u64) -> Self {
        Self::with_pool(problem, seed, WorkerPool::from_env())
    }

    /// Build with an explicit worker pool for the per-user GP shards.
    pub fn with_pool(problem: &Problem, seed: u64, pool: WorkerPool) -> Self {
        GpEiRandom { inner: PerUserGpEi::new(problem, pool), rng: Rng::new(seed) }
    }
}

impl Policy for GpEiRandom {
    fn name(&self) -> String {
        "GP-EI-Random".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        let eligible: Vec<usize> = (0..self.inner.users.len())
            .filter(|&u| self.inner.users[u].has_candidate(ctx.selected))
            .collect();
        if eligible.is_empty() {
            return None;
        }
        let u = eligible[self.rng.below(eligible.len())];
        self.inner.users[u].select(ctx.selected, self.inner.incumbents.value(u))
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.inner.observe(problem, arm, z);
    }
}

/// Ablation A2: **independent per-user GPs, global EIrate argmax**.
///
/// Uses the same device-allocation rule as MM-GP-EI (pick the globally
/// best EIrate) but scores each arm with its owner's *private* GP —
/// isolating the contribution of the shared prior/covariance from the
/// contribution of the global allocation rule.
pub struct MmGpEiIndep {
    users: Vec<UserGpEi>,
    incumbents: Incumbents,
    cost: Vec<f64>,
    pool: WorkerPool,
}

impl MmGpEiIndep {
    /// Build for a problem instance (pool width from `MMGPEI_THREADS`).
    pub fn new(problem: &Problem) -> Self {
        Self::with_pool(problem, WorkerPool::from_env())
    }

    /// Build with an explicit worker pool: shards both the per-user GP
    /// updates and the per-decision EI rescoring.
    pub fn with_pool(problem: &Problem, pool: WorkerPool) -> Self {
        MmGpEiIndep {
            users: (0..problem.n_users).map(|u| UserGpEi::new(problem, u)).collect(),
            incumbents: Incumbents::new(problem.n_users),
            cost: problem.cost.clone(),
            pool,
        }
    }
}

impl Policy for MmGpEiIndep {
    fn name(&self) -> String {
        "GP-EI-MDMT[indep-gp]".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        // EIrate per arm, summing each arm's EI across owning users, each
        // scored by that user's private GP. The O(|𝓛| · owners) EI sweep
        // shards across the pool by contiguous arm ranges; each shard
        // reports its lowest-index argmax and the fixed-order merge below
        // reproduces the serial scan's result exactly — at any thread
        // count (per-arm scores are independent, so shard boundaries
        // cannot change any float).
        let users = &self.users;
        let incumbents = &self.incumbents;
        let cost = &self.cost;
        let n = ctx.problem.n_arms();
        let shard = |range: std::ops::Range<usize>| {
            let mut best_arm = None;
            let mut best_score = f64::NEG_INFINITY;
            for a in range {
                if ctx.selected[a] {
                    continue;
                }
                let mut ei_sum = 0.0;
                for &u in &ctx.problem.arm_users[a] {
                    let li = users[u].local[a];
                    ei_sum += users[u].gp.ei(li, incumbents.value(u));
                }
                let score = ei_sum / cost[a];
                if score > best_score {
                    best_score = score;
                    best_arm = Some(a);
                }
            }
            (best_score, best_arm)
        };
        if !self.pool.engages(n) {
            // Serial fast path: the plain linear scan, allocation-free.
            return shard(0..n).1;
        }
        let shards = self.pool.map_chunks(n, shard);
        let mut best_arm = None;
        let mut best_score = f64::NEG_INFINITY;
        for (score, arm) in shards {
            if arm.is_some() && score > best_score {
                best_score = score;
                best_arm = arm;
            }
        }
        best_arm
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.pool.for_each_chunk_mut(&mut self.users, |chunk| {
            for user in chunk {
                user.observe(arm, z);
            }
        });
        self.incumbents.update_arm(problem, arm, z);
    }
}

/// Regret lower-bound reference: knows the ground truth and immediately
/// runs every user's optimal arm (cheapest-first among optima), then
/// fills with the remaining arms. Not part of the paper; used to sanity-
/// check that no policy beats clairvoyance.
pub struct Oracle {
    /// Pre-computed dispatch order.
    order: Vec<ArmId>,
    cursor: usize,
}

impl Oracle {
    /// Build from the hidden truth.
    pub fn new(problem: &Problem, truth: &Truth) -> Self {
        let mut optimal: Vec<ArmId> =
            (0..problem.n_users).map(|u| truth.best_arm(problem, u)).collect();
        optimal.sort_by(|&a, &b| problem.cost[a].total_cmp(&problem.cost[b]));
        optimal.dedup();
        let mut rest: Vec<ArmId> =
            (0..problem.n_arms()).filter(|a| !optimal.contains(a)).collect();
        rest.sort_by(|&a, &b| problem.cost[a].total_cmp(&problem.cost[b]));
        let mut order = optimal;
        order.extend(rest);
        Oracle { order, cursor: 0 }
    }
}

impl Policy for Oracle {
    fn name(&self) -> String {
        "Oracle".into()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        while self.cursor < self.order.len() {
            let a = self.order[self.cursor];
            self.cursor += 1;
            if !ctx.selected[a] {
                return Some(a);
            }
        }
        None
    }

    fn observe(&mut self, _problem: &Problem, _arm: ArmId, _z: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::sched::DeviceView;

    fn ctx<'a>(p: &'a Problem, selected: &'a [bool], observed: &'a [bool]) -> SchedContext<'a> {
        SchedContext { problem: p, selected, observed, now: 0.0, device: DeviceView::unit(0) }
    }

    fn problem() -> Problem {
        // 3 users × 2 arms, disjoint.
        let user_arms = vec![vec![0, 1], vec![2, 3], vec![4, 5]];
        let arm_users = Problem::compute_arm_users(6, &user_arms);
        Problem {
            name: "base".into(),
            n_users: 3,
            cost: vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 6],
            prior_cov: Mat::eye(6),
        }
    }

    #[test]
    fn round_robin_cycles_users() {
        let p = problem();
        let mut pol = GpEiRoundRobin::new(&p);
        let mut selected = vec![false; 6];
        let observed = vec![false; 6];
        let mut owners = Vec::new();
        for _ in 0..3 {
            let a = pol.select(&ctx(&p, &selected, &observed)).unwrap();
            selected[a] = true;
            owners.push(p.arm_users[a][0]);
        }
        let mut sorted = owners.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "three picks must hit three users: {owners:?}");
    }

    #[test]
    fn round_robin_skips_exhausted_user() {
        let p = problem();
        let mut pol = GpEiRoundRobin::new(&p);
        // User 0 fully selected.
        let selected = vec![true, true, false, false, false, false];
        let observed = vec![true, true, false, false, false, false];
        for _ in 0..4 {
            let a = pol.select(&ctx(&p, &selected, &observed)).unwrap();
            assert!(a >= 2, "user 0 has nothing left");
        }
    }

    #[test]
    fn random_is_seed_deterministic() {
        let p = problem();
        let selected = vec![false; 6];
        let observed = vec![false; 6];
        let picks_a: Vec<_> = {
            let mut pol = GpEiRandom::new(&p, 7);
            (0..5)
                .map(|_| pol.select(&ctx(&p, &selected, &observed)).unwrap())
                .collect()
        };
        let picks_b: Vec<_> = {
            let mut pol = GpEiRandom::new(&p, 7);
            (0..5)
                .map(|_| pol.select(&ctx(&p, &selected, &observed)).unwrap())
                .collect()
        };
        assert_eq!(picks_a, picks_b);
    }

    #[test]
    fn indep_gp_never_picks_selected() {
        let p = problem();
        let mut pol = MmGpEiIndep::new(&p);
        let mut selected = vec![false; 6];
        let observed = vec![false; 6];
        for _ in 0..6 {
            let a = pol.select(&ctx(&p, &selected, &observed)).unwrap();
            assert!(!selected[a]);
            selected[a] = true;
            pol.observe(&p, a, 0.5);
        }
        assert!(pol.select(&ctx(&p, &selected, &selected)).is_none());
    }

    #[test]
    fn oracle_runs_optima_first() {
        let p = problem();
        let truth = Truth { z: vec![0.9, 0.1, 0.2, 0.8, 0.3, 0.7] };
        let mut pol = Oracle::new(&p, &truth);
        let mut selected = vec![false; 6];
        let observed = vec![false; 6];
        let mut first_three = Vec::new();
        for _ in 0..3 {
            let a = pol.select(&ctx(&p, &selected, &observed)).unwrap();
            selected[a] = true;
            first_three.push(a);
        }
        let mut sorted = first_three.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 3, 5], "optimal arms first: {first_three:?}");
    }

    #[test]
    fn user_gp_shares_nothing_across_users() {
        let p = problem();
        let mut pol = GpEiRoundRobin::new(&p);
        // Observation on user 0's arm must not alter user 1's GP.
        let before = pol.inner.users[1].gp.posterior_mean(0);
        pol.observe(&p, 0, 0.99);
        let after = pol.inner.users[1].gp.posterior_mean(0);
        assert_eq!(before, after, "independent GPs must not leak");
        // But user 0's own GP updated.
        assert!((pol.inner.users[0].gp.posterior_mean(0) - 0.99).abs() < 1e-12);
    }
}
