//! Scheduling policies — the paper's §4 contribution and its baselines.
//!
//! A [`Policy`] is consulted by the driver (simulator or live coordinator)
//! every time a device becomes free and is notified of every finished
//! observation. The paper's experiments (§6.1) compare:
//!
//! * [`MmGpEi`] — **GP-EI-MDMT**, Algorithm 1: one shared GP over all
//!   arms; whenever a device frees, run the unselected arm maximizing
//!   `EIrate_t(x) = Σ_i 1(x∈𝓛_i)·EI_{i,t}(x) / c(x)`;
//! * [`GpEiRoundRobin`] — each user runs an independent single-tenant
//!   GP-EI; the service serves users in round-robin order;
//! * [`GpEiRandom`] — same, but the next user is drawn uniformly;
//! * [`Oracle`] — knows the ground truth; runs every user's optimal arm
//!   first (regret lower-bound reference, not in the paper);
//! * ablations: [`MmGpEi::cost_insensitive`] (rank by EI instead of
//!   EIrate) and [`MmGpEiIndep`] (global EIrate argmax but *independent*
//!   per-user GPs — isolates the value of the shared prior).

mod argmax;
mod backend;
mod baselines;
mod fantasy;
mod gp_ucb;
mod mm_gp_ei;

pub use argmax::TournamentTree;
pub use backend::{rescan_eirate, EiBackend, NativeBackend};
pub use baselines::{GpEiRandom, GpEiRoundRobin, MmGpEiIndep, Oracle};
pub use fantasy::MmGpEiFantasy;
pub use gp_ucb::{GpUcbMdmt, GpUcbRoundRobin};
pub use mm_gp_ei::MmGpEi;

use crate::problem::{ArmId, Problem, UserId};

/// How a backend turns per-arm EI sums into dispatch scores.
///
/// Replaces the old boolean-blind `use_cost: bool` plumbing: the third
/// variant could not be expressed as a bool, and call sites read as
/// `eirate(best, selected, ScoreMode::CostRate, device)` instead of an
/// opaque `true`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// Rank by raw `EI(x)` — the paper's cost-insensitive ablation.
    EiOnly,
    /// Rank by `EI(x) / c(x)` — Algorithm 1's EIrate, device-blind.
    CostRate,
    /// Rank by `EI(x) / (c(x, class_d) / s_d)` for the *asking* device —
    /// device-aware EIrate over a per-(arm, device-class)
    /// [`crate::problem::CostModel`]; arms infeasible on the asking
    /// device's class score `−∞` (non-candidates).
    DeviceRate,
}

/// The asking device at a decision point, as visible to a policy.
///
/// On a uniform unit fleet this is `DeviceView::unit(id)` — speed `1.0`,
/// class `0` — and [`ScoreMode::DeviceRate`] scoring degenerates bitwise
/// to [`ScoreMode::CostRate`] (`x / 1.0` and `x · 1.0` are IEEE
/// identities), which is what the byte-parity gates pin.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceView {
    /// Engine device index.
    pub id: usize,
    /// Relative speed `s_d` (execution time = `c(x, class) / s_d`).
    pub speed: f64,
    /// Cost-model class (index into a [`crate::problem::CostModel`]).
    pub class: usize,
}

impl DeviceView {
    /// The view every pre-device-aware call site implicitly assumed:
    /// unit speed, class 0.
    pub fn unit(id: usize) -> Self {
        DeviceView { id, speed: 1.0, class: 0 }
    }
}

/// Incumbent value used for a user with no observation yet.
///
/// The paper's protocol warm-starts two models per user, so the incumbent
/// is always defined once a policy takes over; before that we floor at
/// 0.0 — the natural "no model yet" value for accuracy-like metrics (all
/// paper workloads are accuracies in [0,1] or shifted-non-negative GP
/// samples).
pub const EMPTY_INCUMBENT: f64 = 0.0;

/// Scheduler-visible state at a decision point.
pub struct SchedContext<'a> {
    /// Problem instance (costs, memberships, prior).
    pub problem: &'a Problem,
    /// `selected[x]` — x is not dispatchable: already dispatched
    /// (observed **or** running), or — under tenant churn — *retired*
    /// because every owning tenant has departed (the churn drivers fold
    /// retirement into this mask, so every policy's candidate filter is
    /// churn-correct without changes; a rejoining tenant's unselected
    /// arms flip back to `false`). Algorithm 1 only considers
    /// `𝓛 \ 𝓛_ob ∖ running` as candidates.
    pub selected: &'a [bool],
    /// `observed[x]` — x has finished and its z is known.
    pub observed: &'a [bool],
    /// Current (virtual or wall-clock) time.
    pub now: f64,
    /// The device asking for work. Device-blind policies ignore it;
    /// device-aware ones (e.g. [`MmGpEi::device_aware`]) score
    /// `EI/(c(x, class_d)/s_d)` for exactly this device.
    pub device: DeviceView,
}

impl<'a> SchedContext<'a> {
    /// Iterator over arms that may still be dispatched.
    pub fn candidates(&self) -> impl Iterator<Item = ArmId> + '_ {
        (0..self.problem.n_arms()).filter(move |&a| !self.selected[a])
    }
}

/// A scheduling policy: decides which arm a freed device runs next.
///
/// Policies are *not* `Send`: the PJRT-backed [`EiBackend`] wraps
/// non-thread-safe client handles. The live coordinator keeps the policy
/// on the leader thread and fans work out to device worker threads.
pub trait Policy {
    /// Display name (used in reports and plots).
    fn name(&self) -> String;

    /// A device is free at `ctx.now`; return the arm to run, or `None`
    /// to leave the device idle (only sensible when no candidate is
    /// left). Must not return an already-selected arm.
    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId>;

    /// Observation callback: arm `x` finished with performance `z`.
    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64);

    /// Tenant churn: `user` joined (or rejoined) the service. Returns
    /// whether the policy applied the change **in place**; the default
    /// `false` tells the driver to fall back to a from-scratch rebuild
    /// (reconstruct the policy and replay the observation history), so
    /// baselines keep working under churn without any code. [`MmGpEi`]
    /// overrides this with an incremental join — the tenant's arms are
    /// appended to the live GP/score state in `O(arms · t²)` instead of
    /// the rebuild's `O(t³ + |𝓛|t²)` — validated bit-exact against the
    /// rebuild path by the churn parity gates.
    fn user_joined(&mut self, _problem: &Problem, _user: UserId) -> bool {
        false
    }

    /// Tenant churn: `user` left the service. Same in-place/rebuild
    /// contract as [`Policy::user_joined`]. Note the *driver* owns arm
    /// retirement (folded into `SchedContext::selected`); this callback
    /// lets a policy additionally stop paying for the departed tenant
    /// (freeze its GP sweeps, drop its incumbent).
    fn user_left(&mut self, _problem: &Problem, _user: UserId) -> bool {
        false
    }

    /// Device fleet churn: `device` joined (or rejoined) the fleet.
    /// Same in-place/rebuild contract as the tenant hooks: the default
    /// `false` routes through the engine's from-scratch rebuild, so
    /// every policy is fleet-correct without changes. [`MmGpEi`]
    /// overrides this by delegating to its backend: the shared posterior
    /// and incumbents don't depend on which devices are online, but a
    /// [`ScoreMode::DeviceRate`] backend keys its score buffer and
    /// tournament tree on the last asking device's `(class, speed)`, so
    /// the hook invalidates that cache (the next decision bulk-rescores
    /// for whichever device asks). Pinned bit-identical to the
    /// [`ForceRebuild`] oracle by the fleet parity gates in
    /// `rust/tests/engine_parity.rs` and `benches/fig7_elastic.rs`.
    fn device_joined(&mut self, _problem: &Problem, _device: usize) -> bool {
        false
    }

    /// Device fleet churn: `device` left the fleet (its in-flight job,
    /// if any, was preempted and the arm's decision requeued by the
    /// engine before this callback). Same contract as
    /// [`Policy::device_joined`].
    fn device_left(&mut self, _problem: &Problem, _device: usize) -> bool {
        false
    }
}

/// Adapter that forces the driver's **rebuild** path on every churn
/// event by reporting both hooks unsupported — the from-scratch oracle
/// the incremental join/leave implementations are gated against
/// (`rust/tests/churn.rs`, `benches/fig6_churn.rs`).
pub struct ForceRebuild<P: Policy>(
    /// The wrapped policy.
    pub P,
);

impl<P: Policy> Policy for ForceRebuild<P> {
    fn name(&self) -> String {
        self.0.name()
    }

    fn select(&mut self, ctx: &SchedContext) -> Option<ArmId> {
        self.0.select(ctx)
    }

    fn observe(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        self.0.observe(problem, arm, z);
    }

    // user_joined / user_left / device_joined / device_left: trait
    // defaults (false) — always rebuild.
}

/// Per-user incumbent tracker `z(x_i*(t))` shared by several policies.
#[derive(Clone, Debug)]
pub struct Incumbents {
    best: Vec<Option<f64>>,
}

impl Incumbents {
    /// All-empty incumbents for `n_users`.
    pub fn new(n_users: usize) -> Self {
        Incumbents { best: vec![None; n_users] }
    }

    /// Current incumbent value for user `u` (floored for empty).
    #[inline]
    pub fn value(&self, u: usize) -> f64 {
        self.best[u].unwrap_or(EMPTY_INCUMBENT)
    }

    /// Whether user `u` has at least one observation.
    pub fn has_observation(&self, u: usize) -> bool {
        self.best[u].is_some()
    }

    /// Drop user `u`'s incumbent (tenant departure): subsequent
    /// [`Incumbents::value`] reads fall back to [`EMPTY_INCUMBENT`] until
    /// a new observation — or until a rejoin restores it from the user's
    /// already-finished arms (see [`MmGpEi`]'s churn hooks).
    pub fn clear(&mut self, u: usize) {
        self.best[u] = None;
    }

    /// Fold in observation `z` of an arm owned by user `u`.
    pub fn update(&mut self, u: usize, z: f64) {
        let cur = self.best[u];
        self.best[u] = Some(match cur {
            Some(b) => b.max(z),
            None => z,
        });
    }

    /// Fold an arm observation into all owning users.
    pub fn update_arm(&mut self, problem: &Problem, arm: ArmId, z: f64) {
        for &u in &problem.arm_users[arm] {
            self.update(u, z);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn two_user_problem() -> Problem {
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        Problem {
            name: "t".into(),
            n_users: 2,
            cost: vec![1.0; 4],
            user_arms,
            arm_users,
            prior_mean: vec![0.0; 4],
            prior_cov: Mat::eye(4),
        }
    }

    #[test]
    fn incumbents_track_max() {
        let mut inc = Incumbents::new(2);
        assert_eq!(inc.value(0), EMPTY_INCUMBENT);
        assert!(!inc.has_observation(0));
        inc.update(0, 0.4);
        inc.update(0, 0.2);
        assert_eq!(inc.value(0), 0.4);
        assert!(inc.has_observation(0));
        assert_eq!(inc.value(1), EMPTY_INCUMBENT);
    }

    #[test]
    fn incumbents_update_arm_fans_out() {
        let mut p = two_user_problem();
        // Make arm 1 shared by both users.
        p.user_arms[1].push(1);
        p.arm_users = Problem::compute_arm_users(4, &p.user_arms);
        let mut inc = Incumbents::new(2);
        inc.update_arm(&p, 1, 0.9);
        assert_eq!(inc.value(0), 0.9);
        assert_eq!(inc.value(1), 0.9);
    }

    #[test]
    fn context_candidates_filter_selected() {
        let p = two_user_problem();
        let selected = vec![true, false, false, true];
        let observed = vec![true, false, false, false];
        let ctx = SchedContext {
            problem: &p,
            selected: &selected,
            observed: &observed,
            now: 0.0,
            device: DeviceView::unit(0),
        };
        let cands: Vec<_> = ctx.candidates().collect();
        assert_eq!(cands, vec![1, 2]);
    }

    #[test]
    fn unit_device_view_is_speed_one_class_zero() {
        let d = DeviceView::unit(3);
        assert_eq!(d, DeviceView { id: 3, speed: 1.0, class: 0 });
    }
}
