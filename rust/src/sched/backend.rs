//! Posterior/EI scoring backends for [`super::MmGpEi`].
//!
//! The scheduler's per-decision numeric work — refresh the GP posterior
//! with any new observations and score every candidate arm's EIrate — is
//! abstracted behind [`EiBackend`] so it can be served either by the
//! native rust incremental-Cholesky GP ([`NativeBackend`]) or by the
//! AOT-compiled JAX/Pallas `scheduler_step` artifact executed via PJRT
//! ([`crate::runtime::XlaBackend`]). The two are cross-verified by the
//! integration tests in `rust/tests/backend_parity.rs`.

use crate::gp::{expected_improvement, Gp};
use crate::problem::{ArmId, Problem};

/// Scoring backend: consumes observations, produces per-arm EIrate.
///
/// Not `Send` — see [`super::Policy`].
pub trait EiBackend {
    /// Incorporate the observation `z(x)`.
    fn observe(&mut self, arm: ArmId, z: f64);

    /// Score every arm: `EIrate_t(x) = Σ_i 1(x ∈ 𝓛_i)·EI_{i,t}(x)/c(x)`
    /// (paper Eqs. 4–5). `best[i]` is the incumbent `z(x_i*(t))` per user
    /// and `selected[x]` marks arms that must score `−∞` (already
    /// dispatched). `use_cost = false` gives the cost-insensitive EI
    /// ablation (rank by Eq. 4 instead of Eq. 5).
    fn eirate(&mut self, best: &[f64], selected: &[bool], use_cost: bool) -> Vec<f64>;

    /// Posterior (mean, std) snapshot for diagnostics/tests.
    fn posterior(&mut self) -> (Vec<f64>, Vec<f64>);

    /// Backend label for reports.
    fn label(&self) -> &'static str;
}

/// Native rust backend: incremental-Cholesky GP posterior, O(1)-read
/// mean/std at decision time (see [`crate::gp::Gp`]).
pub struct NativeBackend {
    gp: Gp,
    /// Flattened membership (arm → owning users) copied from the problem
    /// so scoring needs no `Problem` borrow.
    arm_users: Vec<Vec<usize>>,
    cost: Vec<f64>,
}

impl NativeBackend {
    /// Build from a problem's prior and membership structure.
    pub fn new(problem: &Problem) -> Self {
        NativeBackend {
            gp: Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone()),
            arm_users: problem.arm_users.clone(),
            cost: problem.cost.clone(),
        }
    }

    /// Borrow the underlying GP (tests, diagnostics).
    pub fn gp(&self) -> &Gp {
        &self.gp
    }
}

impl EiBackend for NativeBackend {
    fn observe(&mut self, arm: ArmId, z: f64) {
        self.gp.observe(arm, z);
    }

    fn eirate(&mut self, best: &[f64], selected: &[bool], use_cost: bool) -> Vec<f64> {
        let n = self.gp.n_arms();
        let mut out = vec![f64::NEG_INFINITY; n];
        for x in 0..n {
            if selected[x] {
                continue;
            }
            let mu = self.gp.posterior_mean(x);
            let sigma = self.gp.posterior_std(x);
            let mut ei_sum = 0.0;
            for &u in &self.arm_users[x] {
                ei_sum += expected_improvement(mu, sigma, best[u]);
            }
            out[x] = if use_cost { ei_sum / self.cost[x] } else { ei_sum };
        }
        out
    }

    fn posterior(&mut self) -> (Vec<f64>, Vec<f64>) {
        let n = self.gp.n_arms();
        (
            (0..n).map(|x| self.gp.posterior_mean(x)).collect(),
            (0..n).map(|x| self.gp.posterior_std(x)).collect(),
        )
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn problem() -> Problem {
        let user_arms = vec![vec![0, 1], vec![1, 2]];
        let arm_users = Problem::compute_arm_users(3, &user_arms);
        Problem {
            name: "b".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 4.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 3],
            prior_cov: Mat::eye(3),
        }
    }

    #[test]
    fn eirate_masks_selected() {
        let mut b = NativeBackend::new(&problem());
        let scores = b.eirate(&[0.0, 0.0], &[true, false, false], true);
        assert_eq!(scores[0], f64::NEG_INFINITY);
        assert!(scores[1].is_finite() && scores[2].is_finite());
    }

    #[test]
    fn shared_arm_sums_over_users() {
        let mut b = NativeBackend::new(&problem());
        // Arm 1 belongs to both users; with equal incumbents its EI sum
        // is twice a single user's EI for the same (μ,σ).
        let scores_no_cost = b.eirate(&[0.2, 0.2], &[false; 3], false);
        let single = expected_improvement(0.5, 1.0, 0.2);
        assert!((scores_no_cost[0] - single).abs() < 1e-12);
        assert!((scores_no_cost[1] - 2.0 * single).abs() < 1e-12);
    }

    #[test]
    fn cost_divides_score() {
        let mut b = NativeBackend::new(&problem());
        let with_cost = b.eirate(&[0.2, 0.2], &[false; 3], true);
        let without = b.eirate(&[0.2, 0.2], &[false; 3], false);
        assert!((with_cost[2] - without[2] / 4.0).abs() < 1e-12);
    }

    #[test]
    fn observe_shifts_scores() {
        let mut b = NativeBackend::new(&problem());
        let before = b.eirate(&[0.0, 0.0], &[false; 3], true);
        b.observe(0, 0.9);
        let after = b.eirate(&[0.9, 0.0], &[true, false, false], true);
        // Incumbent rose for user 0; arm 1's score must drop (same prior,
        // higher bar for one of its users).
        assert!(after[1] < before[1]);
    }

    #[test]
    fn posterior_snapshot_matches_gp() {
        let mut b = NativeBackend::new(&problem());
        b.observe(1, 0.8);
        let (mu, sd) = b.posterior();
        assert!((mu[1] - 0.8).abs() < 1e-12);
        assert_eq!(sd[1], 0.0);
        assert_eq!(b.label(), "native");
    }
}
