//! Posterior/EI scoring backends for [`super::MmGpEi`].
//!
//! The scheduler's per-decision numeric work — refresh the GP posterior
//! with any new observations and score every candidate arm's EIrate — is
//! abstracted behind [`EiBackend`] so it can be served either by the
//! native rust incremental-Cholesky GP ([`NativeBackend`]) or by the
//! AOT-compiled JAX/Pallas `scheduler_step` artifact executed via PJRT
//! ([`crate::runtime::XlaBackend`], `--features xla`). The two are
//! cross-verified by the integration tests in
//! `rust/tests/backend_parity.rs`.
//!
//! **Incremental scoring.** The naive implementation rescans every arm on
//! every device-free event — `O(|𝓛| · owners)` EI evaluations per
//! decision, the multi-tenant scheduling overhead Ease.ml-style services
//! must keep far below training time. [`NativeBackend`] instead keeps a
//! per-arm EIrate cache invalidated by a *dirty set*: [`crate::gp::Gp`]
//! reports which arms' `(μ, σ)` actually moved on each observation, and
//! incumbent updates invalidate only the arms owned by the affected
//! users, so a decision rescores `O(|dirty|)` arms and the rest are
//! served from cache — with *bit-identical* scores to a full rescan
//! (the cache is only ever skipped for arms whose inputs are unchanged,
//! for which a recompute would reproduce the exact same floats).
//!
//! **Incremental argmax.** Selection is served by a [`TournamentTree`]
//! (segment-max index) over the masked scores, repaired only at leaves
//! whose inputs moved: `O(|dirty| · owners)` EI work plus
//! `O(|dirty| · log |𝓛|)` tree repair per decision, with the same
//! deterministic lowest-index tie-breaking as the linear scan it
//! replaces (hard-gated against the rescan oracle in
//! `benches/perf_hotpath.rs`). One linear pass remains — a branch-
//! friendly byte-compare of the `selected` mask against the last call's
//! (the trait API passes whole masks, not deltas) — but it does no EI
//! math and is orders of magnitude cheaper than the full-scoring scan
//! it replaced.

use super::argmax::TournamentTree;
use super::{DeviceView, ScoreMode};
use crate::gp::{expected_improvement, Gp, KroneckerPrior, ShardedGp};
use crate::problem::{ArmId, CostModel, Problem, UserId};

/// Scoring backend: consumes observations, produces per-arm EIrate.
///
/// Not `Send` — see [`super::Policy`].
pub trait EiBackend {
    /// Incorporate the observation `z(x)`.
    fn observe(&mut self, arm: ArmId, z: f64);

    /// Score every arm for the asking `device`. Under
    /// [`ScoreMode::CostRate`]:
    /// `EIrate_t(x) = Σ_i 1(x ∈ 𝓛_i)·EI_{i,t}(x)/c(x)` (paper Eqs. 4–5);
    /// [`ScoreMode::EiOnly`] is the cost-insensitive ablation (rank by
    /// Eq. 4) and [`ScoreMode::DeviceRate`] divides by the asking
    /// device's *time* `c(x, class_d)/s_d` instead of the device-blind
    /// cost (arms infeasible on `class_d` score `−∞`). `best[i]` is the
    /// incumbent `z(x_i*(t))` per user and `selected[x]` marks arms that
    /// must score `−∞` (already dispatched).
    ///
    /// Returns a borrow of the backend's preallocated score buffer — no
    /// allocation on the per-decision hot path. The slice is valid until
    /// the next call on the backend.
    fn eirate(&mut self, best: &[f64], selected: &[bool], mode: ScoreMode, device: DeviceView) -> &[f64];

    /// Argmax of the current EIrate over unselected arms, with
    /// deterministic lowest-index tie-breaking; `None` when every arm is
    /// masked. The default implementation linearly scans
    /// [`EiBackend::eirate`] (skipping selected arms regardless of the
    /// backend's mask convention — native uses `−∞`, the XLA artifact
    /// `−1e30`); [`NativeBackend`] overrides it with an `O(1)` read of
    /// its tournament-tree index.
    fn select_arm(
        &mut self,
        best: &[f64],
        selected: &[bool],
        mode: ScoreMode,
        device: DeviceView,
    ) -> Option<ArmId> {
        let scores = self.eirate(best, selected, mode, device);
        let mut best_arm = None;
        let mut best_score = f64::NEG_INFINITY;
        for (x, &s) in scores.iter().enumerate() {
            if selected[x] {
                continue;
            }
            if s > best_score {
                best_score = s;
                best_arm = Some(x);
            }
        }
        best_arm
    }

    /// Posterior (mean, std) snapshot for diagnostics/tests.
    fn posterior(&mut self) -> (Vec<f64>, Vec<f64>);

    /// Backend label for reports.
    fn label(&self) -> &'static str;

    /// Tenant churn: `user` joined/rejoined — bring its arms back into
    /// the live scoring state. Returns whether the backend applied the
    /// change in place; the default `false` (taken by the XLA artifact,
    /// whose AOT shapes are fixed) makes [`super::MmGpEi`] report the
    /// event unsupported so the driver rebuilds.
    fn user_joined(&mut self, _problem: &Problem, _user: UserId) -> bool {
        false
    }

    /// Tenant churn: `user` left — stop paying for its arms. Same
    /// in-place/rebuild contract as [`EiBackend::user_joined`].
    fn user_left(&mut self, _problem: &Problem, _user: UserId) -> bool {
        false
    }

    /// Fleet churn: `device` joined (or rejoined). The posterior and EI
    /// sums don't depend on which devices are online, so the default is
    /// a trivially-true no-op; [`NativeBackend`] additionally drops its
    /// assembled score buffer / tournament tree when they were keyed to
    /// a [`ScoreMode::DeviceRate`] asking device — the per-device cache
    /// is stale-by-key once the asking-device set changes — forcing a
    /// bulk reassembly (identical floats, so rebuild-oracle parity
    /// holds) on the next decision.
    fn device_joined(&mut self, _device: usize) -> bool {
        true
    }

    /// Fleet churn: `device` left. Same contract as
    /// [`EiBackend::device_joined`].
    fn device_left(&mut self, _device: usize) -> bool {
        true
    }

    /// The revealed value of `arm` if it has finished, else `None`.
    /// Churn drivers use this to restore a rejoining tenant's incumbent
    /// from its already-finished arms; backends that cannot answer
    /// (default) return `None`, which leaves the incumbent empty.
    fn observed_value(&self, _arm: ArmId) -> Option<f64> {
        None
    }
}

/// The posterior store behind [`NativeBackend`]: either the dense
/// incremental-Cholesky [`Gp`] (the default, and the oracle every parity
/// gate compares against) or the sharded block-Kronecker [`ShardedGp`]
/// selected by `[gp] structure = "sharded"` for multi-tenant priors far
/// above the dense-feasible range. Both expose the same
/// observe/posterior/churn surface — dirty-set reporting included — so
/// the dirty-set → EIrate-cache → tournament-tree invalidation path is
/// store-agnostic and stays bit-stable under either store.
enum GpStore {
    Dense(Gp),
    Sharded(ShardedGp),
}

impl GpStore {
    #[inline]
    fn observe(&mut self, x: ArmId, z: f64) -> &[ArmId] {
        match self {
            GpStore::Dense(gp) => gp.observe(x, z),
            GpStore::Sharded(gp) => gp.observe(x, z),
        }
    }

    #[inline]
    fn posterior_mean(&self, x: ArmId) -> f64 {
        match self {
            GpStore::Dense(gp) => gp.posterior_mean(x),
            GpStore::Sharded(gp) => gp.posterior_mean(x),
        }
    }

    #[inline]
    fn posterior_std(&self, x: ArmId) -> f64 {
        match self {
            GpStore::Dense(gp) => gp.posterior_std(x),
            GpStore::Sharded(gp) => gp.posterior_std(x),
        }
    }

    #[inline]
    fn is_observed(&self, x: ArmId) -> bool {
        match self {
            GpStore::Dense(gp) => gp.is_observed(x),
            GpStore::Sharded(gp) => gp.is_observed(x),
        }
    }

    #[inline]
    fn is_enabled(&self, x: ArmId) -> bool {
        match self {
            GpStore::Dense(gp) => gp.is_enabled(x),
            GpStore::Sharded(gp) => gp.is_enabled(x),
        }
    }

    fn enable_arm(&mut self, x: ArmId) {
        match self {
            GpStore::Dense(gp) => gp.enable_arm(x),
            GpStore::Sharded(gp) => gp.enable_arm(x),
        }
    }

    fn disable_arm(&mut self, x: ArmId) {
        match self {
            GpStore::Dense(gp) => gp.disable_arm(x),
            GpStore::Sharded(gp) => gp.disable_arm(x),
        }
    }

    fn n_arms(&self) -> usize {
        match self {
            GpStore::Dense(gp) => gp.n_arms(),
            GpStore::Sharded(gp) => gp.n_arms(),
        }
    }
}

/// Native rust backend: incremental-Cholesky GP posterior, O(1)-read
/// mean/std at decision time (see [`crate::gp::Gp`]), and a dirty-set
/// EIrate cache so each decision rescores only the arms whose posterior
/// or owner incumbents moved since the last decision.
pub struct NativeBackend {
    gp: GpStore,
    /// Flattened membership (arm → owning users) copied from the problem
    /// so scoring needs no `Problem` borrow.
    arm_users: Vec<Vec<usize>>,
    /// Inverse membership (user → owned arms) for incumbent-driven cache
    /// invalidation.
    user_arms: Vec<Vec<ArmId>>,
    cost: Vec<f64>,
    /// Per-class cost table `class_cost[class][arm]` from the
    /// [`CostModel`] (`+∞` = infeasible on that class); a single row
    /// equal to `cost` when built without a model, so
    /// [`ScoreMode::DeviceRate`] on class 0 at unit speed reproduces
    /// [`ScoreMode::CostRate`] bitwise.
    class_cost: Vec<Vec<f64>>,
    /// Cached per-arm summed EI `Σ_i 1(x∈𝓛_i)·EI_{i,t}(x)` (cost division
    /// and the selected-mask are applied at output time).
    ei_cache: Vec<f64>,
    /// Incumbent vector the cache was computed against (bit-compared).
    last_best: Vec<f64>,
    /// `dirty[x]` — arm x needs rescoring before the next read.
    dirty: Vec<bool>,
    /// Dense list of dirty arms (avoids an O(|𝓛|) flag scan per decision).
    dirty_arms: Vec<ArmId>,
    /// Preallocated output buffer for [`EiBackend::eirate`]. Assembled
    /// *incrementally*: an entry is rewritten only when its inputs
    /// (EI cache, selected bit, cost mode) changed since the last call.
    score_buf: Vec<f64>,
    /// Tournament-tree argmax index over `score_buf`, repaired leaf-by-
    /// leaf alongside the incremental assembly — decisions read the
    /// argmax from the root instead of scanning `O(|𝓛|)` scores.
    tree: TournamentTree,
    /// Selected mask `score_buf`/`tree` were assembled against.
    last_selected: Vec<bool>,
    /// Normalized `(mode, class, speed-bits)` key of the last assembly
    /// (see [`NativeBackend::mode_key`]); `None` forces the next call to
    /// assemble every arm. Device-blind modes normalize to
    /// `(mode, 0, 1.0)` so alternating devices never invalidates them;
    /// under [`ScoreMode::DeviceRate`] the buffer/tree are per-device
    /// state, rebuilt whenever a different `(class, speed)` asks.
    last_key: Option<(ScoreMode, usize, u64)>,
    /// Tenant churn: which users are currently active. A shared arm's GP
    /// maintenance is dropped only once *every* owner has left.
    active_users: Vec<bool>,
    /// Revealed `z(x)` per finished arm (NaN = not finished). Kept
    /// verbatim — the GP's pinned mean picks up float residue from later
    /// sweeps, and incumbent restoration on a tenant rejoin must use the
    /// *exact* observed values to stay bit-identical to a rebuild that
    /// replays the observation history.
    observed_z: Vec<f64>,
}

impl NativeBackend {
    /// Shared construction core: any posterior store plus the membership
    /// structure and device-blind cost vector.
    fn from_parts(gp: GpStore, arm_users: Vec<Vec<usize>>, user_arms: Vec<Vec<ArmId>>, cost: Vec<f64>) -> Self {
        let n = gp.n_arms();
        let n_users = user_arms.len();
        debug_assert_eq!(arm_users.len(), n);
        debug_assert_eq!(cost.len(), n);
        NativeBackend {
            gp,
            arm_users,
            user_arms,
            class_cost: vec![cost.clone()],
            cost,
            ei_cache: vec![0.0; n],
            // NaN sentinel: no incumbent vector bit-matches it, so the
            // first decision scores every arm.
            last_best: vec![f64::NAN; n_users],
            dirty: vec![true; n],
            dirty_arms: (0..n).collect(),
            score_buf: vec![f64::NEG_INFINITY; n],
            tree: TournamentTree::new(n),
            last_selected: vec![false; n],
            last_key: None,
            active_users: vec![true; n_users],
            observed_z: vec![f64::NAN; n],
        }
    }

    /// Build from a problem's prior and membership structure, with the
    /// uniform single-class cost table (every device class sees
    /// `problem.cost`).
    pub fn new(problem: &Problem) -> Self {
        Self::from_parts(
            GpStore::Dense(Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone())),
            problem.arm_users.clone(),
            problem.user_arms.clone(),
            problem.cost.clone(),
        )
    }

    /// Build over the sharded block-Kronecker store ([`ShardedGp`])
    /// instead of the dense factor, taking membership and costs from the
    /// problem. The problem's own `prior_mean`/`prior_cov` are **not**
    /// read — `prior` is the structured form of the same prior (the
    /// `[gp] structure = "sharded"` config path constructs it from the
    /// workload recipe; `rust/tests/sharded_gp.rs` gates the parity).
    pub fn sharded(problem: &Problem, prior: KroneckerPrior) -> Self {
        assert_eq!(
            prior.n_arms(),
            problem.n_arms(),
            "sharded prior shape must match the problem arm set"
        );
        assert_eq!(prior.n_users(), problem.n_users, "sharded prior tenant count must match the problem");
        Self::from_parts(
            GpStore::Sharded(ShardedGp::new(prior)),
            problem.arm_users.clone(),
            problem.user_arms.clone(),
            problem.cost.clone(),
        )
    }

    /// Build over the sharded store with the canonical user-major
    /// membership (tenant `u` exclusively owns arms `u·m..(u+1)·m`) and
    /// an explicit device-blind cost vector — no dense `Problem` needed,
    /// which is the constructor the 10⁴–10⁶-tenant scaling sweeps use
    /// (materializing an `O(n²)` prior covariance is exactly what the
    /// sharded store exists to avoid).
    pub fn sharded_user_major(prior: KroneckerPrior, cost: Vec<f64>) -> Self {
        let n = prior.n_arms();
        let m = prior.n_models();
        assert_eq!(cost.len(), n, "cost vector must have one entry per arm");
        let user_arms: Vec<Vec<ArmId>> = (0..prior.n_users()).map(|u| (u * m..(u + 1) * m).collect()).collect();
        let arm_users: Vec<Vec<usize>> = (0..n).map(|x| vec![x / m]).collect();
        Self::from_parts(GpStore::Sharded(ShardedGp::new(prior)), arm_users, user_arms, cost)
    }

    /// Build with a per-(arm, device-class) [`CostModel`]: the model's
    /// dense table is copied in (so the backend stays `'static`) and
    /// serves [`ScoreMode::DeviceRate`] lookups; the scheduler-visible
    /// `problem` should be the engine's `sched_view` (Remark 1) so the
    /// estimated-vs-true cost split carries over unchanged.
    pub fn with_cost_model(problem: &Problem, model: &dyn CostModel) -> Self {
        let mut b = NativeBackend::new(problem);
        b.class_cost = model.class_table(problem.n_arms());
        b
    }

    /// Normalized assembly cache key: device-blind modes collapse to
    /// `(mode, 0, 1.0)` so which device asks never invalidates them.
    #[inline]
    fn mode_key(mode: ScoreMode, device: DeviceView) -> (ScoreMode, usize, u64) {
        match mode {
            ScoreMode::DeviceRate => (mode, device.class, device.speed.to_bits()),
            ScoreMode::EiOnly | ScoreMode::CostRate => (mode, 0, 1.0f64.to_bits()),
        }
    }

    /// Borrow the underlying dense GP (tests, diagnostics, the
    /// `rescan_eirate` oracle).
    ///
    /// # Panics
    /// When the backend runs the sharded store — callers that support
    /// both use [`NativeBackend::sharded_gp`] to discriminate.
    pub fn gp(&self) -> &Gp {
        match &self.gp {
            GpStore::Dense(gp) => gp,
            GpStore::Sharded(_) => {
                panic!("NativeBackend::gp(): backend runs the sharded store; use sharded_gp() instead")
            }
        }
    }

    /// Borrow the sharded store, if this backend was built with one
    /// ([`NativeBackend::sharded`] / [`NativeBackend::sharded_user_major`]).
    pub fn sharded_gp(&self) -> Option<&ShardedGp> {
        match &self.gp {
            GpStore::Dense(_) => None,
            GpStore::Sharded(gp) => Some(gp),
        }
    }

    /// Number of arms the next decision will rescore (tests/metrics).
    pub fn pending_dirty(&self) -> usize {
        self.dirty_arms.len()
    }

    /// Mark one arm dirty (idempotent).
    #[inline]
    fn mark_dirty(dirty: &mut [bool], dirty_arms: &mut Vec<ArmId>, x: ArmId) {
        if !dirty[x] {
            dirty[x] = true;
            // pallas-lint: allow(R6) — dirty-arm worklist is with_capacity(n) at construction and the `dirty` bitmap caps it at one entry per arm, so the push never reallocates (alloc_counter gate).
            dirty_arms.push(x);
        }
    }

    /// Masked, mode-normalized score of arm `x` from the EI cache. At
    /// unit speed on class 0 of the uniform table, the
    /// [`ScoreMode::DeviceRate`] arm `ei / (c / 1.0)` is bitwise
    /// `ei / c` — the [`ScoreMode::CostRate`] score — which the
    /// uniform-fleet byte-parity gates rely on.
    #[inline]
    fn assemble_score(&self, x: ArmId, selected: &[bool], mode: ScoreMode, device: DeviceView) -> f64 {
        if selected[x] {
            return f64::NEG_INFINITY;
        }
        match mode {
            ScoreMode::EiOnly => self.ei_cache[x],
            ScoreMode::CostRate => self.ei_cache[x] / self.cost[x],
            ScoreMode::DeviceRate => {
                let c = self.class_cost[device.class][x];
                if c.is_infinite() {
                    // Infeasible on the asking device's class: never a
                    // candidate for this device.
                    f64::NEG_INFINITY
                } else {
                    self.ei_cache[x] / (c / device.speed)
                }
            }
        }
    }

    /// Bring `ei_cache`, `score_buf`, and the tournament tree up to date
    /// with `(best, selected, mode, device)` — the shared core of
    /// [`EiBackend::eirate`] and [`EiBackend::select_arm`]. Work done:
    ///
    /// 1. incumbent-driven invalidation (bit-compared per user);
    /// 2. EI rescoring of the dirty set, `O(|dirty| · owners)`;
    /// 3. score assembly + `O(log |𝓛|)` tree repair for exactly the arms
    ///    whose inputs moved: dirty arms, arms whose `selected` bit
    ///    flipped (found by a cheap bool-diff sweep), or — on a
    ///    mode/asking-device change, a fleet-churn invalidation, or the
    ///    first call — everything at once via an `O(|𝓛|)` bulk tree
    ///    rebuild.
    ///
    /// No allocation in any path (all buffers are preallocated).
    fn refresh(&mut self, best: &[f64], selected: &[bool], mode: ScoreMode, device: DeviceView) {
        debug_assert_eq!(best.len(), self.user_arms.len());
        let n = self.ei_cache.len();
        debug_assert_eq!(selected.len(), n);
        // 1. Incumbent-driven invalidation: a user whose incumbent moved
        //    dirties every arm they own. Bit-compare so the cache is
        //    only trusted when a recompute would be a float-for-float
        //    no-op.
        for u in 0..best.len() {
            if best[u].to_bits() != self.last_best[u].to_bits() {
                self.last_best[u] = best[u];
                for &x in &self.user_arms[u] {
                    Self::mark_dirty(&mut self.dirty, &mut self.dirty_arms, x);
                }
            }
        }
        // 2. Rescore the dirty set — O(|dirty| · owners) instead of the
        //    full O(|𝓛| · owners) rescan.
        let key = Self::mode_key(mode, device);
        let rebuild_all = self.last_key != Some(key);
        for &x in &self.dirty_arms {
            let mu = self.gp.posterior_mean(x);
            let sigma = self.gp.posterior_std(x);
            let mut ei_sum = 0.0;
            for &u in &self.arm_users[x] {
                ei_sum += expected_improvement(mu, sigma, best[u]);
            }
            self.ei_cache[x] = ei_sum;
            self.dirty[x] = false;
            // 3a. Re-assemble the dirty arm's masked score and repair its
            //     tree path (skipped when a bulk rebuild is coming).
            if !rebuild_all {
                let s = self.assemble_score(x, selected, mode, device);
                self.score_buf[x] = s;
                self.tree.update(x, s);
            }
        }
        self.dirty_arms.clear();
        if rebuild_all {
            // 3b. Mode/asking-device change, fleet-churn invalidation, or
            //     first call: every masked score is stale at once —
            //     assemble the whole buffer and rebuild the tree
            //     bottom-up in O(|𝓛|).
            for x in 0..n {
                self.score_buf[x] = self.assemble_score(x, selected, mode, device);
            }
            self.last_selected.copy_from_slice(selected);
            self.last_key = Some(key);
            self.tree.rebuild_from(&self.score_buf);
            return;
        }
        // 3c. Mask-driven re-assembly: arms whose selected bit flipped
        //     since the last call (a cheap bool-diff sweep — no EI work).
        for x in 0..n {
            if self.last_selected[x] != selected[x] {
                self.last_selected[x] = selected[x];
                let s = self.assemble_score(x, selected, mode, device);
                self.score_buf[x] = s;
                self.tree.update(x, s);
            }
        }
    }
}

impl EiBackend for NativeBackend {
    fn observe(&mut self, arm: ArmId, z: f64) {
        // Tenant churn: an arm dispatched before its tenant departed can
        // complete afterwards. Bring it back just long enough to fold the
        // observation into the shared posterior (the knowledge must not
        // be dropped — it prices every correlated arm), then freeze it
        // again. The enable/disable round trip is bit-exact (see
        // `Gp::enable_arm`), so this leaves the posterior identical to a
        // from-scratch replay of the same observation sequence.
        let was_disabled = !self.gp.is_enabled(arm);
        if was_disabled {
            self.gp.enable_arm(arm);
        }
        let first = !self.gp.is_observed(arm);
        // The GP reports exactly the arms whose (μ, σ) moved; only those
        // can change their EI under an unchanged incumbent vector.
        let changed = self.gp.observe(arm, z);
        for &x in changed {
            Self::mark_dirty(&mut self.dirty, &mut self.dirty_arms, x);
        }
        if first && self.gp.is_observed(arm) {
            self.observed_z[arm] = z;
        }
        if was_disabled {
            self.gp.disable_arm(arm);
        }
    }

    fn eirate(&mut self, best: &[f64], selected: &[bool], mode: ScoreMode, device: DeviceView) -> &[f64] {
        self.refresh(best, selected, mode, device);
        &self.score_buf
    }

    fn select_arm(
        &mut self,
        best: &[f64],
        selected: &[bool],
        mode: ScoreMode,
        device: DeviceView,
    ) -> Option<ArmId> {
        self.refresh(best, selected, mode, device);
        // O(1) argmax read off the tournament tree. −∞ means every arm is
        // masked or infeasible for the asking device (unselected feasible
        // arms always score ≥ 0: EI ≥ 0, cost > 0, speed > 0).
        let (score, arm) = self.tree.best();
        if score == f64::NEG_INFINITY {
            None
        } else {
            debug_assert!(!selected[arm], "tree argmax must respect the mask");
            Some(arm)
        }
    }

    fn posterior(&mut self) -> (Vec<f64>, Vec<f64>) {
        let n = self.gp.n_arms();
        (
            (0..n).map(|x| self.gp.posterior_mean(x)).collect(),
            (0..n).map(|x| self.gp.posterior_std(x)).collect(),
        )
    }

    fn label(&self) -> &'static str {
        match &self.gp {
            GpStore::Dense(_) => "native",
            GpStore::Sharded(_) => "sharded",
        }
    }

    /// Incremental join: re-enable the tenant's arms in the live GP
    /// (bit-exact catch-up on the observations that arrived while it was
    /// away — see [`Gp::enable_arm`]) and mark them dirty so the next
    /// decision rescoring folds them back into the score buffer and
    /// repairs their tournament-tree leaves. `O(arms · t²)` instead of a
    /// from-scratch rebuild.
    fn user_joined(&mut self, _problem: &Problem, user: UserId) -> bool {
        self.active_users[user] = true;
        for &x in &self.user_arms[user] {
            self.gp.enable_arm(x);
            Self::mark_dirty(&mut self.dirty, &mut self.dirty_arms, x);
        }
        true
    }

    /// Incremental leave: freeze the GP maintenance of every arm whose
    /// owners have now *all* departed. The arms themselves are masked out
    /// of the score buffer/tree by the driver (retirement is folded into
    /// the `selected` mask), so scoring needs no extra work here.
    fn user_left(&mut self, _problem: &Problem, user: UserId) -> bool {
        self.active_users[user] = false;
        for &x in &self.user_arms[user] {
            if !self.arm_users[x].iter().any(|&u| self.active_users[u]) {
                self.gp.disable_arm(x);
            }
        }
        true
    }

    fn observed_value(&self, arm: ArmId) -> Option<f64> {
        if self.gp.is_observed(arm) {
            Some(self.observed_z[arm])
        } else {
            None
        }
    }

    /// In-place fleet join: the EI cache is untouched (posterior and
    /// incumbents don't see devices), but a [`ScoreMode::DeviceRate`]
    /// score buffer/tree is keyed to the last asking device and the
    /// asking-device set just changed — drop the assembly key so the
    /// next decision bulk-reassembles (identical floats from the same
    /// EI cache, so this stays bit-exact vs the rebuild oracle).
    fn device_joined(&mut self, _device: usize) -> bool {
        if matches!(self.last_key, Some((ScoreMode::DeviceRate, _, _))) {
            self.last_key = None;
        }
        true
    }

    /// In-place fleet leave: same invalidation as
    /// [`NativeBackend::device_joined`] (the departed device may be the
    /// one the buffer was assembled for).
    fn device_left(&mut self, _device: usize) -> bool {
        if matches!(self.last_key, Some((ScoreMode::DeviceRate, _, _))) {
            self.last_key = None;
        }
        true
    }
}

/// Reference scorer: the full `O(|𝓛| · owners)` rescan [`NativeBackend`]
/// replaces. Recomputes every arm's EIrate from the GP posterior with no
/// caching — the correctness oracle for the dirty-set cache (property
/// tests, `benches/perf_hotpath.rs`) and the before/after baseline of the
/// §Perf iteration log.
pub fn rescan_eirate(
    gp: &Gp,
    arm_users: &[Vec<usize>],
    cost: &[f64],
    best: &[f64],
    selected: &[bool],
    mode: ScoreMode,
    device: DeviceView,
) -> Vec<f64> {
    let n = gp.n_arms();
    let mut out = vec![f64::NEG_INFINITY; n];
    for (x, slot) in out.iter_mut().enumerate() {
        if selected[x] {
            continue;
        }
        // Under DeviceRate, `cost` is the asking class's column of the
        // cost-model table (+∞ = infeasible there → stays −∞).
        if mode == ScoreMode::DeviceRate && cost[x].is_infinite() {
            continue;
        }
        let mu = gp.posterior_mean(x);
        let sigma = gp.posterior_std(x);
        let mut ei_sum = 0.0;
        for &u in &arm_users[x] {
            ei_sum += expected_improvement(mu, sigma, best[u]);
        }
        *slot = match mode {
            ScoreMode::EiOnly => ei_sum,
            ScoreMode::CostRate => ei_sum / cost[x],
            ScoreMode::DeviceRate => ei_sum / (cost[x] / device.speed),
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::problem::PerClassCost;

    fn problem() -> Problem {
        let user_arms = vec![vec![0, 1], vec![1, 2]];
        let arm_users = Problem::compute_arm_users(3, &user_arms);
        Problem {
            name: "b".into(),
            n_users: 2,
            cost: vec![1.0, 2.0, 4.0],
            user_arms,
            arm_users,
            prior_mean: vec![0.5; 3],
            prior_cov: Mat::eye(3),
        }
    }

    fn d0() -> DeviceView {
        DeviceView::unit(0)
    }

    #[test]
    fn eirate_masks_selected() {
        let mut b = NativeBackend::new(&problem());
        let scores = b.eirate(&[0.0, 0.0], &[true, false, false], ScoreMode::CostRate, d0());
        assert_eq!(scores[0], f64::NEG_INFINITY);
        assert!(scores[1].is_finite() && scores[2].is_finite());
    }

    #[test]
    fn shared_arm_sums_over_users() {
        let mut b = NativeBackend::new(&problem());
        // Arm 1 belongs to both users; with equal incumbents its EI sum
        // is twice a single user's EI for the same (μ,σ).
        let scores_no_cost = b.eirate(&[0.2, 0.2], &[false; 3], ScoreMode::EiOnly, d0());
        let single = expected_improvement(0.5, 1.0, 0.2);
        assert!((scores_no_cost[0] - single).abs() < 1e-12);
        assert!((scores_no_cost[1] - 2.0 * single).abs() < 1e-12);
    }

    #[test]
    fn cost_divides_score() {
        let mut b = NativeBackend::new(&problem());
        let with_cost = b.eirate(&[0.2, 0.2], &[false; 3], ScoreMode::CostRate, d0()).to_vec();
        let without = b.eirate(&[0.2, 0.2], &[false; 3], ScoreMode::EiOnly, d0()).to_vec();
        assert!((with_cost[2] - without[2] / 4.0).abs() < 1e-12);
    }

    #[test]
    fn observe_shifts_scores() {
        let mut b = NativeBackend::new(&problem());
        let before = b.eirate(&[0.0, 0.0], &[false; 3], ScoreMode::CostRate, d0()).to_vec();
        b.observe(0, 0.9);
        let after = b.eirate(&[0.9, 0.0], &[true, false, false], ScoreMode::CostRate, d0()).to_vec();
        // Incumbent rose for user 0; arm 1's score must drop (same prior,
        // higher bar for one of its users).
        assert!(after[1] < before[1]);
    }

    #[test]
    fn device_rate_on_unit_device_is_bitwise_cost_rate() {
        // The degeneration identity the fleet byte-parity gates rely on:
        // ei / (c / 1.0) == ei / c bitwise, for every arm.
        let p = problem();
        let mut aware = NativeBackend::new(&p);
        let mut blind = NativeBackend::new(&p);
        for b in [&mut aware, &mut blind] {
            b.observe(0, 0.7);
        }
        let best = [0.7, 0.0];
        let selected = [true, false, false];
        let a = aware.eirate(&best, &selected, ScoreMode::DeviceRate, d0()).to_vec();
        let c = blind.eirate(&best, &selected, ScoreMode::CostRate, d0()).to_vec();
        for x in 0..3 {
            assert_eq!(a[x].to_bits(), c[x].to_bits(), "arm {x}");
        }
    }

    #[test]
    fn device_rate_divides_by_time_not_cost() {
        // Speed 2 halves execution time, doubling every feasible score.
        let p = problem();
        let mut b = NativeBackend::new(&p);
        let best = [0.2, 0.2];
        let slow = b.eirate(&best, &[false; 3], ScoreMode::DeviceRate, d0()).to_vec();
        let fast_dev = DeviceView { id: 1, speed: 2.0, class: 0 };
        let fast = b.eirate(&best, &[false; 3], ScoreMode::DeviceRate, fast_dev).to_vec();
        for x in 0..3 {
            assert!((fast[x] - 2.0 * slow[x]).abs() < 1e-12, "arm {x}");
        }
    }

    #[test]
    fn infeasible_arm_scores_neg_inf_and_is_never_selected() {
        let p = problem();
        // Class 1 has memory limit 3: arm 2 (base cost 4) can't run there.
        let model = PerClassCost::from_problem(&p, vec![1.0, 1.5], vec![f64::INFINITY, 3.0]);
        let mut b = NativeBackend::with_cost_model(&p, &model);
        let small_dev = DeviceView { id: 1, speed: 1.0, class: 1 };
        let best = [0.0, 0.0];
        let scores = b.eirate(&best, &[false; 3], ScoreMode::DeviceRate, small_dev).to_vec();
        assert_eq!(scores[2], f64::NEG_INFINITY);
        assert!(scores[0].is_finite() && scores[1].is_finite());
        // With everything else masked, the infeasible arm is not picked
        // even though it is the only unselected arm.
        let pick = b.select_arm(&best, &[true, true, false], ScoreMode::DeviceRate, small_dev);
        assert_eq!(pick, None);
        // A class-0 device (no limit) still serves it.
        let pick = b.select_arm(&best, &[true, true, false], ScoreMode::DeviceRate, d0());
        assert_eq!(pick, Some(2));
    }

    #[test]
    fn alternating_devices_match_rescan_per_device() {
        // DeviceRate scores must be exact for whichever device asks,
        // including after per-device cache rebuilds, and the fleet-churn
        // hooks must not corrupt the assembly.
        let p = problem();
        let model = PerClassCost::from_problem(&p, vec![1.0, 2.0], vec![f64::INFINITY, 3.0]);
        let mut b = NativeBackend::with_cost_model(&p, &model);
        let table = model.class_table(3);
        let devs = [d0(), DeviceView { id: 1, speed: 0.5, class: 1 }, DeviceView { id: 2, speed: 2.0, class: 0 }];
        let mut selected = vec![false; 3];
        let mut best = vec![0.0f64; 2];
        let zs = [0.7, 0.4, 0.9];
        for step in 0..3 {
            for &dev in &devs {
                let cached = b.eirate(&best, &selected, ScoreMode::DeviceRate, dev).to_vec();
                let oracle = rescan_eirate(
                    b.gp(),
                    &p.arm_users,
                    &table[dev.class],
                    &best,
                    &selected,
                    ScoreMode::DeviceRate,
                    dev,
                );
                for x in 0..3 {
                    assert!(
                        cached[x] == oracle[x],
                        "step {step} dev {} arm {x}: {} vs {}",
                        dev.id,
                        cached[x],
                        oracle[x]
                    );
                }
            }
            b.observe(step, zs[step]);
            selected[step] = true;
            for &u in &p.arm_users[step] {
                best[u] = best[u].max(zs[step]);
            }
            // Fleet churn mid-sequence: invalidates the per-device
            // assembly, must reproduce identical floats afterwards.
            assert!(b.device_left(1));
            assert!(b.device_joined(1));
        }
    }

    #[test]
    fn posterior_snapshot_matches_gp() {
        let mut b = NativeBackend::new(&problem());
        b.observe(1, 0.8);
        let (mu, sd) = b.posterior();
        assert!((mu[1] - 0.8).abs() < 1e-12);
        assert_eq!(sd[1], 0.0);
        assert_eq!(b.label(), "native");
    }

    #[test]
    fn cache_matches_rescan_bit_for_bit() {
        // Drive a full observation sequence with evolving incumbents and
        // masks; at every step the cached scores must equal the
        // uncached rescan exactly (same floats, same argmax).
        let p = problem();
        let mut b = NativeBackend::new(&p);
        let mut selected = vec![false; 3];
        let mut best = vec![0.0f64; 2];
        let zs = [0.7, 0.4, 0.9];
        for step in 0..3 {
            for mode in [ScoreMode::CostRate, ScoreMode::EiOnly] {
                let cached = b.eirate(&best, &selected, mode, d0()).to_vec();
                let oracle =
                    rescan_eirate(b.gp(), &p.arm_users, &p.cost, &best, &selected, mode, d0());
                for x in 0..3 {
                    assert!(
                        cached[x] == oracle[x],
                        "step {step} mode {mode:?} arm {x}: {} vs {}",
                        cached[x],
                        oracle[x]
                    );
                }
            }
            b.observe(step, zs[step]);
            selected[step] = true;
            for &u in &p.arm_users[step] {
                best[u] = best[u].max(zs[step]);
            }
        }
    }

    #[test]
    fn clean_decisions_rescore_nothing() {
        // Identity prior: observing arm 0 moves only arm 0's posterior;
        // with unchanged incumbents a repeat decision rescores 0 arms.
        let p = problem();
        let mut b = NativeBackend::new(&p);
        let best = [0.0, 0.0];
        let _ = b.eirate(&best, &[false; 3], ScoreMode::CostRate, d0());
        assert_eq!(b.pending_dirty(), 0);
        let _ = b.eirate(&best, &[false; 3], ScoreMode::CostRate, d0());
        assert_eq!(b.pending_dirty(), 0);
        // An observation dirties exactly the moved arm (identity prior)…
        b.observe(0, 0.3);
        assert_eq!(b.pending_dirty(), 1);
        // …and an incumbent move dirties exactly that user's arms.
        let _ = b.eirate(&[0.3, 0.0], &[true, false, false], ScoreMode::CostRate, d0());
        assert_eq!(b.pending_dirty(), 0);
        let _ = b.eirate(&[0.4, 0.0], &[true, false, false], ScoreMode::CostRate, d0());
        // user 0 owns arms {0, 1}: both were rescored and drained.
        assert_eq!(b.pending_dirty(), 0);
    }

    #[test]
    fn select_arm_matches_linear_scan_at_every_step() {
        // The tournament-tree argmax must agree with the linear scan of
        // the (oracle-verified) score buffer at every decision, through
        // observations, incumbent moves, mask growth, and cost-mode
        // flips.
        let p = problem();
        let mut b = NativeBackend::new(&p);
        let mut selected = vec![false; 3];
        let mut best = vec![0.0f64; 2];
        let zs = [0.7, 0.4, 0.9];
        for step in 0..3 {
            for mode in [ScoreMode::CostRate, ScoreMode::EiOnly, ScoreMode::DeviceRate] {
                let scan = {
                    let scores = b.eirate(&best, &selected, mode, d0());
                    let mut arg = None;
                    let mut max = f64::NEG_INFINITY;
                    for (x, &s) in scores.iter().enumerate() {
                        if !selected[x] && s > max {
                            max = s;
                            arg = Some(x);
                        }
                    }
                    arg
                };
                let tree = b.select_arm(&best, &selected, mode, d0());
                assert_eq!(tree, scan, "step {step} mode {mode:?}");
            }
            b.observe(step, zs[step]);
            selected[step] = true;
            for &u in &p.arm_users[step] {
                best[u] = best[u].max(zs[step]);
            }
        }
        // Exhausted: every arm masked → no candidate.
        assert_eq!(b.select_arm(&best, &selected, ScoreMode::CostRate, d0()), None);
    }

    #[test]
    fn default_select_arm_matches_native_override() {
        // The trait's default (linear-scan) implementation and the
        // native tournament override must be interchangeable.
        struct Linear(NativeBackend);
        impl EiBackend for Linear {
            fn observe(&mut self, arm: ArmId, z: f64) {
                self.0.observe(arm, z);
            }
            fn eirate(&mut self, best: &[f64], selected: &[bool], mode: ScoreMode, device: DeviceView) -> &[f64] {
                self.0.eirate(best, selected, mode, device)
            }
            // select_arm: default linear scan.
            fn posterior(&mut self) -> (Vec<f64>, Vec<f64>) {
                self.0.posterior()
            }
            fn label(&self) -> &'static str {
                "linear"
            }
        }
        let p = problem();
        let mut tree = NativeBackend::new(&p);
        let mut lin = Linear(NativeBackend::new(&p));
        let mut selected = vec![false; 3];
        let mut best = vec![0.0f64; 2];
        let zs = [0.6, 0.8, 0.2];
        for step in 0..3 {
            assert_eq!(
                tree.select_arm(&best, &selected, ScoreMode::CostRate, d0()),
                lin.select_arm(&best, &selected, ScoreMode::CostRate, d0()),
                "step {step}"
            );
            tree.observe(step, zs[step]);
            lin.observe(step, zs[step]);
            selected[step] = true;
            for &u in &p.arm_users[step] {
                best[u] = best[u].max(zs[step]);
            }
        }
    }

    #[test]
    fn sharded_store_matches_dense_backend_at_rho_zero() {
        // 2 tenants × 2 models, independent tenants (ρ = 0): the sharded
        // store must reproduce the dense backend's scores, picks, and
        // posterior snapshot bit for bit, whichever constructor built it.
        let c = Mat::from_rows(&[&[1.0, 0.3], &[0.3, 1.0]]);
        let prior = KroneckerPrior::constant_mean(2, c, 0.0, 0.5).unwrap();
        let (mean, cov) = prior.dense_prior();
        let user_arms = vec![vec![0, 1], vec![2, 3]];
        let arm_users = Problem::compute_arm_users(4, &user_arms);
        let cost = vec![1.0, 2.0, 1.0, 3.0];
        let p = Problem {
            name: "s".into(),
            n_users: 2,
            cost: cost.clone(),
            user_arms,
            arm_users,
            prior_mean: mean,
            prior_cov: cov,
        };
        let mut dense = NativeBackend::new(&p);
        let mut shard = NativeBackend::sharded(&p, prior.clone());
        let mut major = NativeBackend::sharded_user_major(prior, cost);
        assert_eq!(dense.label(), "native");
        assert_eq!(shard.label(), "sharded");
        assert!(shard.sharded_gp().is_some());
        assert!(dense.sharded_gp().is_none());
        let mut selected = vec![false; 4];
        let mut best = vec![0.0f64; 2];
        let zs = [0.7, 0.4, 0.9, 0.2];
        for step in 0..4 {
            let pick = dense.select_arm(&best, &selected, ScoreMode::CostRate, d0());
            assert_eq!(pick, shard.select_arm(&best, &selected, ScoreMode::CostRate, d0()), "step {step}");
            assert_eq!(pick, major.select_arm(&best, &selected, ScoreMode::CostRate, d0()), "step {step}");
            let ds = dense.eirate(&best, &selected, ScoreMode::CostRate, d0()).to_vec();
            let ss = shard.eirate(&best, &selected, ScoreMode::CostRate, d0()).to_vec();
            let ms = major.eirate(&best, &selected, ScoreMode::CostRate, d0()).to_vec();
            for x in 0..4 {
                assert_eq!(ds[x].to_bits(), ss[x].to_bits(), "step {step} arm {x}");
                assert_eq!(ds[x].to_bits(), ms[x].to_bits(), "step {step} arm {x} (user-major)");
            }
            dense.observe(step, zs[step]);
            shard.observe(step, zs[step]);
            major.observe(step, zs[step]);
            selected[step] = true;
            for &u in &p.arm_users[step] {
                best[u] = best[u].max(zs[step]);
            }
        }
        let (dm, dsd) = dense.posterior();
        let (sm, ssd) = shard.posterior();
        for x in 0..4 {
            assert_eq!(dm[x].to_bits(), sm[x].to_bits(), "posterior mean arm {x}");
            assert_eq!(dsd[x].to_bits(), ssd[x].to_bits(), "posterior std arm {x}");
        }
    }

    #[test]
    fn incumbent_move_invalidates_owned_arms_only() {
        let p = problem();
        let mut b = NativeBackend::new(&p);
        let first = b.eirate(&[0.0, 0.0], &[false; 3], ScoreMode::CostRate, d0()).to_vec();
        // Raise user 1's incumbent: arms 1 and 2 (owned by user 1) must
        // drop; arm 0 (user 0 only) must be byte-identical from cache.
        let second = b.eirate(&[0.0, 0.5], &[false; 3], ScoreMode::CostRate, d0()).to_vec();
        assert_eq!(first[0], second[0], "unowned arm served from cache");
        assert!(second[1] < first[1]);
        assert!(second[2] < first[2]);
    }
}
