//! Figure 2 — "Performance of Different Model Selection Algorithms with
//! a Single Computation Device".
//!
//! Regenerates both panels: Azure and DeepLearning, three policies
//! (GP-EI-MDMT / GP-EI-Round-Robin / GP-EI-Random), M = 1, mean ± 1σ over
//! protocol re-samplings. Prints the instantaneous-regret series the
//! paper plots plus the time-to-target speedup that supports the "up to
//! 5× faster than round robin" claim on Azure.
//!
//! Run: `cargo bench --bench fig2_single_device`
//! CI:  `cargo bench --bench fig2_single_device -- --smoke --json reports/BENCH_fig2_single_device.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::report::{Direction, RunReport};

fn main() {
    let opts = BenchOpts::from_env_args();
    let seeds = opts.seeds("MMGPEI_SEEDS", 10, 2);
    let mut report = RunReport::new("fig2_single_device", 0, opts.smoke);
    for dataset in ["azure", "deeplearning"] {
        let cfg = ExperimentConfig {
            name: format!("fig2-{dataset}"),
            dataset: dataset.into(),
            policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
            devices: vec![1],
            seeds,
            // Seed-sweep pool width; byte-identical output at any value.
            threads: opts.threads(),
            ..Default::default()
        };
        let res = run_experiment(&cfg).expect("fig2 sweep");
        res.push_kpis(&mut report, &format!("{dataset}/"), &[0.05, 0.01]);
        println!("\n=== Figure 2 [{dataset}] — single device, {} seeds ===", cfg.seeds);
        let mut table = Table::new(&["policy", "cumulative regret", "t: regret ≤ 0.05", "t: regret ≤ 0.01"]);
        let mut t_mm = (f64::NAN, f64::NAN);
        let mut t_rr = (f64::NAN, f64::NAN);
        for cell in &res.cells {
            let tt = |cut: f64| {
                let hits: Vec<f64> =
                    cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
                if hits.is_empty() {
                    f64::NAN
                } else {
                    mmgpei::metrics::mean_std(&hits).0
                }
            };
            let (t05, t01) = (tt(0.05), tt(0.01));
            if cell.policy == "mdmt" {
                t_mm = (t05, t01);
            }
            if cell.policy == "round-robin" {
                t_rr = (t05, t01);
            }
            table.row(vec![
                cell.policy.clone(),
                format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
                format!("{t05:.2}"),
                format!("{t01:.2}"),
            ]);
        }
        println!("{}", table.to_markdown());
        println!(
            "speedup of MDMT over round-robin to reach regret ≤ 0.05: {:.2}×, ≤ 0.01: {:.2}×",
            t_rr.0 / t_mm.0,
            t_rr.1 / t_mm.1
        );
        // The paper's headline claim as gated KPIs (NaN speedups — a
        // cutoff some seed never reached — are dropped by push_kpi).
        report.push_kpi(
            format!("{dataset}/speedup_mdmt_vs_rr_t0.05"),
            t_rr.0 / t_mm.0,
            Direction::HigherIsBetter,
        );
        report.push_kpi(
            format!("{dataset}/speedup_mdmt_vs_rr_t0.01"),
            t_rr.1 / t_mm.1,
            Direction::HigherIsBetter,
        );
        // Mean-curve series (what the shaded plot shows), downsampled.
        println!("\nseries (t, mean inst. regret, σ):");
        for cell in &res.cells {
            let pts: Vec<String> = cell
                .curve
                .iter()
                .step_by(cell.curve.len() / 8)
                .map(|(t, m, s)| format!("({t:.0}, {m:.4}±{s:.4})"))
                .collect();
            println!("  {:<14} {}", cell.policy, pts.join(" "));
        }
    }
    println!("\npaper shape: MDMT ≫ baselines on Azure; ≈ parity on DeepLearning (σ=0.04)");
    opts.finish(&report);
}
