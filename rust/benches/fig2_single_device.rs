//! Figure 2 — "Performance of Different Model Selection Algorithms with
//! a Single Computation Device".
//!
//! Regenerates both panels: Azure and DeepLearning, three policies
//! (GP-EI-MDMT / GP-EI-Round-Robin / GP-EI-Random), M = 1, mean ± 1σ over
//! protocol re-samplings. Prints the instantaneous-regret series the
//! paper plots plus the time-to-target speedup that supports the "up to
//! 5× faster than round robin" claim on Azure.
//!
//! Run: `cargo bench --bench fig2_single_device`

use mmgpei::bench::Table;
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;

fn seeds() -> u64 {
    std::env::var("MMGPEI_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(10)
}

fn main() {
    for dataset in ["azure", "deeplearning"] {
        let cfg = ExperimentConfig {
            name: format!("fig2-{dataset}"),
            dataset: dataset.into(),
            policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
            devices: vec![1],
            seeds: seeds(),
            ..Default::default()
        };
        let res = run_experiment(&cfg).expect("fig2 sweep");
        println!("\n=== Figure 2 [{dataset}] — single device, {} seeds ===", cfg.seeds);
        let mut table = Table::new(&["policy", "cumulative regret", "t: regret ≤ 0.05", "t: regret ≤ 0.01"]);
        let mut t_mm = (f64::NAN, f64::NAN);
        let mut t_rr = (f64::NAN, f64::NAN);
        for cell in &res.cells {
            let tt = |cut: f64| {
                let hits: Vec<f64> =
                    cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
                if hits.is_empty() {
                    f64::NAN
                } else {
                    mmgpei::metrics::mean_std(&hits).0
                }
            };
            let (t05, t01) = (tt(0.05), tt(0.01));
            if cell.policy == "mdmt" {
                t_mm = (t05, t01);
            }
            if cell.policy == "round-robin" {
                t_rr = (t05, t01);
            }
            table.row(vec![
                cell.policy.clone(),
                format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
                format!("{t05:.2}"),
                format!("{t01:.2}"),
            ]);
        }
        println!("{}", table.to_markdown());
        println!(
            "speedup of MDMT over round-robin to reach regret ≤ 0.05: {:.2}×, ≤ 0.01: {:.2}×",
            t_rr.0 / t_mm.0,
            t_rr.1 / t_mm.1
        );
        // Mean-curve series (what the shaded plot shows), downsampled.
        println!("\nseries (t, mean inst. regret, σ):");
        for cell in &res.cells {
            let pts: Vec<String> = cell
                .curve
                .iter()
                .step_by(cell.curve.len() / 8)
                .map(|(t, m, s)| format!("({t:.0}, {m:.4}±{s:.4})"))
                .collect();
            println!("  {:<14} {}", cell.policy, pts.join(" "));
        }
    }
    println!("\npaper shape: MDMT ≫ baselines on Azure; ≈ parity on DeepLearning (σ=0.04)");
}
