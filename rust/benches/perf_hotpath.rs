//! §Perf P1 — the scheduler decision hot path, per layer and per backend.
//!
//! Measures, as a function of problem size:
//!  * native incremental GP: cost of one `observe` (posterior refresh)
//!    and one full EIrate scoring pass;
//!  * the naive O(t³) recompute the incremental path replaces (the
//!    before/after of the §Perf iteration log);
//!  * the AOT XLA artifact: one full `scheduler_step` execution via PJRT
//!    (requires `make artifacts`; skipped otherwise);
//!  * end-to-end decision latency inside the live coordinator.
//!
//! Run: `cargo bench --bench perf_hotpath`

use mmgpei::bench::{Bencher, Table};
use mmgpei::prng::Rng;
use mmgpei::runtime::{default_artifact_dir, XlaBackend};
use mmgpei::sched::{EiBackend, NativeBackend};
use mmgpei::testutil::gen;
use std::hint::black_box;
use std::time::Duration;

fn main() {
    let bench = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(800),
        max_iters: 100_000,
        min_iters: 3,
    };
    println!("=== §Perf P1: decision hot path ===\n");
    let mut table = Table::new(&["operation", "L (arms)", "t (obs)", "mean", "p99"]);

    for (n_users, models_per_user) in [(8usize, 8usize), (16, 8), (16, 32), (32, 32)] {
        let l = n_users * models_per_user;
        let mut rng = Rng::new(42);
        let (problem, truth) = gen::problem(&mut rng, n_users, models_per_user);
        let t_obs = l / 2;

        // Native backend pre-warmed with t_obs observations.
        let mut native = NativeBackend::new(&problem);
        let mut selected = vec![false; l];
        for a in 0..t_obs {
            native.observe(a, truth.z[a]);
            selected[a] = true;
        }
        let best: Vec<f64> = (0..n_users)
            .map(|u| {
                problem.user_arms[u]
                    .iter()
                    .filter(|&&a| a < t_obs)
                    .map(|&a| truth.z[a])
                    .fold(0.0, f64::max)
            })
            .collect();

        // (a) EIrate scoring pass (reads cached posterior — O(L·N̄)).
        let stats = bench.run("eirate", || {
            black_box(native.eirate(black_box(&best), black_box(&selected), true))
        });
        table.row(vec![
            "native eirate scan".into(),
            l.to_string(),
            t_obs.to_string(),
            mmgpei::bench::fmt_duration(stats.mean),
            mmgpei::bench::fmt_duration(stats.p99),
        ]);

        // (b) incremental observe, amortized over a fresh sequential run
        // of t_obs observations (what the simulator actually pays; a
        // per-call measurement would be dominated by cloning the GP's
        // flat buffers inside the timed region).
        let stats = bench.run("observe", || {
            let mut gp = mmgpei::gp::Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone());
            for a in 0..t_obs {
                gp.observe(a, truth.z[a]);
            }
            black_box(gp.posterior_mean(0))
        });
        table.row(vec![
            "native observe (amortized/obs)".into(),
            l.to_string(),
            t_obs.to_string(),
            mmgpei::bench::fmt_duration(stats.mean / t_obs as u32),
            mmgpei::bench::fmt_duration(stats.p99 / t_obs as u32),
        ]);

        // (c) the naive full recompute the incremental path replaces.
        let stats = bench.run("recompute", || black_box(native.gp().recompute_posterior_slow()));
        table.row(vec![
            "naive posterior recompute".into(),
            l.to_string(),
            t_obs.to_string(),
            mmgpei::bench::fmt_duration(stats.mean),
            mmgpei::bench::fmt_duration(stats.p99),
        ]);

        // (d) XLA artifact scheduler_step (if artifacts exist and fit).
        if let Ok(mut xla) = XlaBackend::new(&problem, &default_artifact_dir()) {
            for a in 0..t_obs {
                xla.observe(a, truth.z[a]);
            }
            let stats = bench.run("xla", || {
                black_box(xla.eirate(black_box(&best), black_box(&selected), true))
            });
            table.row(vec![
                "xla scheduler_step (PJRT)".into(),
                l.to_string(),
                t_obs.to_string(),
                mmgpei::bench::fmt_duration(stats.mean),
                mmgpei::bench::fmt_duration(stats.p99),
            ]);
        }
    }
    println!("{}", table.to_markdown());

    // End-to-end: decision latency inside the live coordinator.
    println!("\n--- live coordinator decision latency (azure, 4 devices) ---");
    let data = mmgpei::workload::azure();
    let mut rng = Rng::new(5);
    let split = data.protocol_split(&mut rng, 8);
    let (problem, truth) = data.make_problem(&split);
    for backend in ["native", "xla"] {
        let mut policy: Box<dyn mmgpei::sched::Policy> = match backend {
            "native" => Box::new(mmgpei::sched::MmGpEi::new(&problem)),
            _ => match XlaBackend::new(&problem, &default_artifact_dir()) {
                Ok(b) => Box::new(mmgpei::sched::MmGpEi::with_backend(&problem, Box::new(b))),
                Err(_) => {
                    println!("xla: skipped (run `make artifacts`)");
                    continue;
                }
            },
        };
        let report = mmgpei::coordinator::serve(
            &problem,
            &truth,
            policy.as_mut(),
            &mmgpei::coordinator::ServeConfig {
                n_devices: 4,
                time_scale: 0.0005,
                warm_start_per_user: 2,
                verbose: false,
            },
        );
        println!(
            "{backend:>7}: mean {:?}, max {:?} over {} decisions (makespan {:?})",
            report.mean_decision_latency(),
            report.max_decision_latency(),
            report.decision_latencies.len(),
            report.makespan
        );
    }
}
