//! §Perf P1 — the scheduler decision hot path, per layer and per backend.
//!
//! Measures, as a function of problem size:
//!  * native incremental GP: cost of one `observe` (posterior refresh)
//!    and one full EIrate scoring pass;
//!  * the naive O(t³) recompute the incremental path replaces (the
//!    before/after of the §Perf iteration log);
//!  * **cached vs rescan** (§Perf P1b): the dirty-set incremental EIrate
//!    cache against the full per-decision rescan it replaces, on a
//!    many-users workload — amortized per-decision cost over a whole
//!    serving run, with an up-front bit-identical argmax check;
//!  * **scaling sweep** (§Perf P2): ns/decision and ns/observe across
//!    tenant counts for the fused observe kernels + tournament argmax,
//!    with tournament-vs-rescan parity hard-gated at every size
//!    (`MMGPEI_GP_STRUCTURE=sharded` swaps in the sharded store — every
//!    mode, including `--smoke`, so CI can determinism-gate it);
//!  * **sharded store** (§Perf P2s): sharded-vs-dense parity gates
//!    (bitwise at ρ = 0, 1e-7 relative at ρ > 0), then the 10⁴–10⁶
//!    tenant scaling sweep dense can't reach — `MMGPEI_P2_USERS` picks
//!    the grid (full runs only), `scaling/ns_per_observe@u{N}x16` is
//!    gated sub-quadratic in N, and serving throughput lands as
//!    `throughput/decisions_per_sec@u{N}x16`;
//!  * the AOT XLA artifact: one full `scheduler_step` execution via PJRT
//!    (requires `--features xla` + `make artifacts`; skipped otherwise);
//!  * end-to-end decision latency inside the live coordinator.
//!
//! With `--json` the wall-clock numbers land in the report's `timings`
//! section (warn-only in CI) and the P1b argmax-parity check lands in
//! `kpis` as a mismatch count (hard-gated at 0). `--smoke` skips the
//! timing loops entirely and emits only the deterministic parity KPIs.
//!
//! Run: `cargo bench --bench perf_hotpath`
//! CI:  `cargo bench --bench perf_hotpath -- --smoke --json reports/BENCH_perf_hotpath.json`

use mmgpei::bench::{BenchOpts, Bencher, Table};
use mmgpei::gp::{Gp, KroneckerPrior, ShardedGp};
use mmgpei::kernels::{Kernel, Matern52};
use mmgpei::prng::Rng;
use mmgpei::problem::{Problem, Truth};
use mmgpei::report::{Direction, RunReport, TimingEntry};
use mmgpei::runtime::{default_artifact_dir, XlaBackend};
use mmgpei::sched::{rescan_eirate, DeviceView, EiBackend, NativeBackend, ScoreMode};
use mmgpei::testutil::gen;
use mmgpei::workload::{synthetic_gp, SyntheticConfig};
use std::hint::black_box;
use std::time::Duration;

fn main() {
    let opts = BenchOpts::from_env_args();
    let mut report = RunReport::new("perf_hotpath", 42, opts.smoke);
    if !opts.smoke {
        micro_benches(&mut report);
    }

    let mut mismatches = cached_vs_rescan(&mut report, opts.smoke);
    mismatches += scaling_sweep(&mut report, opts.smoke);
    mismatches += sharded_sweep(&mut report, opts.smoke);

    if !opts.smoke {
        coordinator_latency(&mut report);
    }
    // Write the report first (the mismatch KPI is evidence worth keeping),
    // then hard-fail: parity is a correctness invariant, not a preference,
    // and it must break CI with or without a checked-in baseline.
    opts.finish(&report);
    if mismatches > 0 {
        eprintln!("FAIL: {mismatches} argmax parity mismatches vs the rescan oracle (must be 0)");
        std::process::exit(1);
    }
}

fn micro_benches(report: &mut RunReport) {
    let bench = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(800),
        max_iters: 100_000,
        min_iters: 3,
    };
    println!("=== §Perf P1: decision hot path ===\n");
    let mut table = Table::new(&["operation", "L (arms)", "t (obs)", "mean", "p99"]);

    for (n_users, models_per_user) in [(8usize, 8usize), (16, 8), (16, 32), (32, 32)] {
        let l = n_users * models_per_user;
        let mut rng = Rng::new(42);
        let (problem, truth) = gen::problem(&mut rng, n_users, models_per_user);
        let t_obs = l / 2;

        // Native backend pre-warmed with t_obs observations.
        let mut native = NativeBackend::new(&problem);
        let mut selected = vec![false; l];
        for a in 0..t_obs {
            native.observe(a, truth.z[a]);
            selected[a] = true;
        }
        let best: Vec<f64> = (0..n_users)
            .map(|u| {
                problem.user_arms[u]
                    .iter()
                    .filter(|&&a| a < t_obs)
                    .map(|&a| truth.z[a])
                    .fold(0.0, f64::max)
            })
            .collect();

        let mut record = |stats: &mmgpei::bench::BenchStats, row_label: &str, table: &mut Table| {
            report.push_timing(TimingEntry::from(&mmgpei::bench::BenchStats {
                name: format!("{}/L{l}", stats.name),
                ..stats.clone()
            }));
            table.row(vec![
                row_label.into(),
                l.to_string(),
                t_obs.to_string(),
                mmgpei::bench::fmt_duration(stats.mean),
                mmgpei::bench::fmt_duration(stats.p99),
            ]);
        };

        // (a) full EIrate scoring pass — every arm rescored from the
        // cached posterior, O(L·N̄) EI evaluations (the per-decision cost
        // the dirty-set cache replaces; see §P1b for the serving-loop
        // comparison).
        let stats = bench.run("eirate-rescan", || {
            black_box(rescan_eirate(
                native.gp(),
                black_box(&problem.arm_users),
                black_box(&problem.cost),
                black_box(&best),
                black_box(&selected),
                ScoreMode::CostRate,
                DeviceView::unit(0),
            ))
        });
        record(&stats, "eirate full rescan", &mut table);

        // (a') steady-state cached read — unchanged posterior and
        // incumbents, so only the O(L) mask/cost assembly runs.
        let stats = bench.run("eirate-cached", || {
            let s = native.eirate(black_box(&best), black_box(&selected), ScoreMode::CostRate, DeviceView::unit(0));
            black_box(s[s.len() - 1])
        });
        record(&stats, "eirate cached (clean decision)", &mut table);

        // (b) incremental observe, amortized over a fresh sequential run
        // of t_obs observations (what the simulator actually pays; a
        // per-call measurement would be dominated by cloning the GP's
        // flat buffers inside the timed region).
        let stats = bench.run("observe", || {
            let mut gp = mmgpei::gp::Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone());
            for a in 0..t_obs {
                gp.observe(a, truth.z[a]);
            }
            black_box(gp.posterior_mean(0))
        });
        let amortized = mmgpei::bench::BenchStats {
            name: stats.name.clone(),
            iters: stats.iters,
            mean: stats.mean / t_obs as u32,
            p50: stats.p50 / t_obs as u32,
            p95: stats.p95 / t_obs as u32,
            p99: stats.p99 / t_obs as u32,
            min: stats.min / t_obs as u32,
            max: stats.max / t_obs as u32,
        };
        record(&amortized, "native observe (amortized/obs)", &mut table);

        // (c) the naive full recompute the incremental path replaces.
        let stats = bench.run("recompute", || black_box(native.gp().recompute_posterior_slow()));
        record(&stats, "naive posterior recompute", &mut table);

        // (d) XLA artifact scheduler_step (if artifacts exist and fit).
        if let Ok(mut xla) = XlaBackend::new(&problem, &default_artifact_dir()) {
            for a in 0..t_obs {
                xla.observe(a, truth.z[a]);
            }
            let stats = bench.run("xla", || {
                let s = xla.eirate(black_box(&best), black_box(&selected), ScoreMode::CostRate, DeviceView::unit(0));
                black_box(s[s.len() - 1])
            });
            record(&stats, "xla scheduler_step (PJRT)", &mut table);
        }
    }
    println!("{}", table.to_markdown());
}

/// One full serving run driven through the cached dirty-set scorer:
/// observe → incumbent update → eirate + tournament-tree argmax, for
/// every arm in `order`. Returns a fold of the scores (keeps the
/// optimizer honest) and appends each decision's tree-served argmax to
/// `picks` when provided — the picks the rescan oracle's linear scan
/// must reproduce bit for bit.
fn drive_cached(
    problem: &Problem,
    truth: &Truth,
    order: &[usize],
    picks: Option<&mut Vec<Option<usize>>>,
) -> f64 {
    drive_backend(NativeBackend::new(problem), problem, truth, order, picks)
}

/// [`drive_cached`] over a caller-built backend — the §P2/§P2s hook that
/// lets the same serving run exercise the dense or the sharded store.
fn drive_backend(
    mut backend: NativeBackend,
    problem: &Problem,
    truth: &Truth,
    order: &[usize],
    mut picks: Option<&mut Vec<Option<usize>>>,
) -> f64 {
    let mut selected = vec![false; problem.n_arms()];
    let mut best = vec![0.0f64; problem.n_users];
    let mut acc = 0.0;
    for &a in order {
        backend.observe(a, truth.z[a]);
        selected[a] = true;
        for &u in &problem.arm_users[a] {
            best[u] = best[u].max(truth.z[a]);
        }
        let dev = DeviceView::unit(0);
        let scores = backend.eirate(&best, &selected, ScoreMode::CostRate, dev);
        acc += scores[scores.len() - 1];
        if let Some(p) = picks.as_mut() {
            p.push(backend.select_arm(&best, &selected, ScoreMode::CostRate, dev));
        }
    }
    acc
}

/// The same serving run scored by the full per-decision rescan.
fn drive_rescan(
    problem: &Problem,
    truth: &Truth,
    order: &[usize],
    mut picks: Option<&mut Vec<Option<usize>>>,
) -> f64 {
    let mut gp = mmgpei::gp::Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone());
    let mut selected = vec![false; problem.n_arms()];
    let mut best = vec![0.0f64; problem.n_users];
    let mut acc = 0.0;
    for &a in order {
        gp.observe(a, truth.z[a]);
        selected[a] = true;
        for &u in &problem.arm_users[a] {
            best[u] = best[u].max(truth.z[a]);
        }
        let scores = rescan_eirate(
            &gp,
            &problem.arm_users,
            &problem.cost,
            &best,
            &selected,
            ScoreMode::CostRate,
            DeviceView::unit(0),
        );
        acc += scores[scores.len() - 1];
        if let Some(p) = picks.as_mut() {
            p.push(argmax(&scores));
        }
    }
    acc
}

fn argmax(scores: &[f64]) -> Option<usize> {
    let mut arg = None;
    let mut best = f64::NEG_INFINITY;
    for (x, &s) in scores.iter().enumerate() {
        if s > best {
            best = s;
            arg = Some(x);
        }
    }
    arg
}

/// §Perf P1b — the acceptance benchmark for the dirty-set cache: the
/// many-users scenario (64 tenants × 16 models, per-user independent
/// blocks), amortized per-decision cost of cached vs full-rescan scoring
/// over a half-exhausting serving run, with bit-identical argmax
/// verification up front (the cached side's picks come from the
/// tournament-tree index, so this gate also pins the tree against the
/// linear-scan oracle). The mismatch count lands in the report as a
/// parity KPI *and* is returned to `main`, which exits non-zero on any
/// divergence — the invariant holds in every mode, baseline or not.
fn cached_vs_rescan(report: &mut RunReport, smoke: bool) -> usize {
    println!("\n=== §Perf P1b: cached (dirty-set) vs full-rescan EIrate, many users ===\n");
    let bench = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(1200),
        max_iters: 1_000,
        min_iters: 3,
    };
    let mut table =
        Table::new(&["scorer", "users", "L (arms)", "decisions", "mean/decision", "speedup"]);
    let mut total_mismatches = 0usize;
    for (n_users, n_models) in [(16usize, 16usize), (64, 16)] {
        let cfg = SyntheticConfig { n_users, n_models, ..Default::default() };
        report.fold_config(&format!("p1b n_users={n_users} n_models={n_models}"));
        let (problem, truth) = synthetic_gp(&cfg, 0xCACE);
        let l = problem.n_arms();
        let n_decisions = l / 2;
        // A deterministic scattered half of the arm set (stride-7 picks,
        // deduped), observed in ascending order.
        let mut order: Vec<usize> = (0..n_decisions).map(|i| (i * 7 + 3) % l).collect();
        order.sort_unstable();
        order.dedup();
        let n_decisions = order.len();

        // Correctness gate: the cached scorer must pick bit-identically
        // to the rescan scorer at every single decision.
        let mut picks_cached = Vec::with_capacity(n_decisions);
        let mut picks_rescan = Vec::with_capacity(n_decisions);
        drive_cached(&problem, &truth, &order, Some(&mut picks_cached));
        drive_rescan(&problem, &truth, &order, Some(&mut picks_rescan));
        let mismatches = picks_cached.iter().zip(&picks_rescan).filter(|(c, r)| c != r).count();
        total_mismatches += mismatches;
        report.push_kpi(
            format!("parity/cached_vs_rescan_mismatches@u{n_users}x{n_models}"),
            mismatches as f64,
            Direction::LowerIsBetter,
        );
        println!(
            "parity u{n_users}x{n_models}: {mismatches}/{n_decisions} diverging argmax decisions (must be 0)"
        );

        if smoke {
            continue; // Timing loops are wall-clock noise; smoke wants determinism.
        }
        let s_cached =
            bench.run("cached", || black_box(drive_cached(&problem, &truth, &order, None)));
        let s_rescan =
            bench.run("rescan", || black_box(drive_rescan(&problem, &truth, &order, None)));
        let per = |d: Duration| d / n_decisions as u32;
        let speedup = s_rescan.mean.as_secs_f64() / s_cached.mean.as_secs_f64();
        report.push_timing(TimingEntry::flat(
            format!("p1b/cached_per_decision@u{n_users}x{n_models}"),
            n_decisions as u64,
            per(s_cached.mean).as_nanos() as f64,
        ));
        report.push_timing(TimingEntry::flat(
            format!("p1b/rescan_per_decision@u{n_users}x{n_models}"),
            n_decisions as u64,
            per(s_rescan.mean).as_nanos() as f64,
        ));
        table.row(vec![
            "full rescan".into(),
            n_users.to_string(),
            l.to_string(),
            n_decisions.to_string(),
            mmgpei::bench::fmt_duration(per(s_rescan.mean)),
            "1.00×".into(),
        ]);
        table.row(vec![
            "dirty-set cache".into(),
            n_users.to_string(),
            l.to_string(),
            n_decisions.to_string(),
            mmgpei::bench::fmt_duration(per(s_cached.mean)),
            format!("{speedup:.2}×"),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("(selections verified bit-identical before timing; target ≥ 5× on 64 users)");
    total_mismatches
}

/// §Perf P2 — user-count scaling sweep: how the fused observe kernels and
/// the tournament argmax hold up as the tenant count grows. Per size:
///
/// * **parity gate** (every mode, incl. `--smoke`): the tree-served picks
///   of a half-exhausting serving run must match the rescan oracle's
///   linear scan bit for bit; mismatches land as a hard-gated KPI and in
///   `main`'s exit code;
/// * **ns/decision** and **ns/observe** (full runs only): amortized
///   serving cost per decision (observe + incumbent fold + dirty rescore
///   + tree argmax) and per fused GP observation, as both KPIs
///   (`scaling/ns_per_*` — regressions flagged by `mmgpei compare`) and
///   timing entries. Smoke reports stay byte-identical because wall-clock
///   numbers are excluded there by construction.
fn scaling_sweep(report: &mut RunReport, smoke: bool) -> usize {
    let sharded = p2_structure_sharded();
    let structure = if sharded { "sharded" } else { "dense" };
    println!("\n=== §Perf P2: user-count scaling (fused observe + tournament argmax, {structure} store) ===\n");
    let sizes: &[(usize, usize)] = if smoke { &[(8, 8), (16, 8)] } else { &[(16, 16), (32, 16), (64, 16), (96, 16)] };
    let bench = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(1000),
        max_iters: 10_000,
        min_iters: 3,
    };
    let mut table = Table::new(&["users", "L (arms)", "decisions", "ns/decision", "ns/observe"]);
    let mut total_mismatches = 0usize;
    for &(n_users, n_models) in sizes {
        let cfg = SyntheticConfig { n_users, n_models, ..Default::default() };
        report.fold_config(&format!("p2 n_users={n_users} n_models={n_models}"));
        if sharded {
            // Folded only when selected so dense reports keep their
            // baseline-stamped config hash (the `[gp]` convention).
            report.fold_config("p2 structure=sharded");
        }
        let (problem, truth) = synthetic_gp(&cfg, 0x5CA1E);
        let l = problem.n_arms();
        let mut order: Vec<usize> = (0..l / 2).map(|i| (i * 7 + 3) % l).collect();
        order.sort_unstable();
        order.dedup();
        let n_decisions = order.len();
        // The env-selected store under test; ρ = 0 keeps the sharded
        // variant bitwise against the same rescan oracle.
        let prior = sharded.then(|| kron_prior(&cfg, &problem));
        let make_backend = || match &prior {
            Some(p) => NativeBackend::sharded(&problem, p.clone()),
            None => NativeBackend::new(&problem),
        };

        // Parity gate: tournament-tree picks vs the rescan oracle.
        let mut picks_tree = Vec::with_capacity(n_decisions);
        let mut picks_rescan = Vec::with_capacity(n_decisions);
        drive_backend(make_backend(), &problem, &truth, &order, Some(&mut picks_tree));
        drive_rescan(&problem, &truth, &order, Some(&mut picks_rescan));
        let mismatches = picks_tree.iter().zip(&picks_rescan).filter(|(t, r)| t != r).count();
        total_mismatches += mismatches;
        report.push_kpi(
            format!("parity/tournament_vs_rescan_mismatches@u{n_users}x{n_models}"),
            mismatches as f64,
            Direction::LowerIsBetter,
        );
        println!(
            "parity u{n_users}x{n_models}: {mismatches}/{n_decisions} diverging tournament-vs-rescan picks (must be 0)"
        );
        if smoke {
            continue; // Wall-clock numbers are noise; smoke gates parity only.
        }

        // ns/decision: one full serving run (observe → incumbent fold →
        // dirty rescore → tree argmax per decision), amortized.
        let s_drive =
            bench.run("drive", || black_box(drive_backend(make_backend(), &problem, &truth, &order, None)));
        let ns_decision = s_drive.mean.as_nanos() as f64 / n_decisions as f64;
        // ns/observe: the fused GP observation pass alone, amortized over
        // a fresh sequential run (same protocol as §P1's observe group).
        let s_obs = bench.run("observe", || match &prior {
            Some(p) => {
                let mut gp = ShardedGp::new(p.clone());
                for &a in &order {
                    gp.observe(a, truth.z[a]);
                }
                black_box(gp.posterior_mean(0))
            }
            None => {
                let mut gp = Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone());
                for &a in &order {
                    gp.observe(a, truth.z[a]);
                }
                black_box(gp.posterior_mean(0))
            }
        });
        let ns_observe = s_obs.mean.as_nanos() as f64 / n_decisions as f64;
        report.push_kpi(format!("scaling/ns_per_decision@u{n_users}x{n_models}"), ns_decision, Direction::LowerIsBetter);
        report.push_kpi(format!("scaling/ns_per_observe@u{n_users}x{n_models}"), ns_observe, Direction::LowerIsBetter);
        report.push_timing(TimingEntry::flat(
            format!("p2/ns_per_decision@u{n_users}x{n_models}"),
            n_decisions as u64,
            ns_decision,
        ));
        report.push_timing(TimingEntry::flat(
            format!("p2/ns_per_observe@u{n_users}x{n_models}"),
            n_decisions as u64,
            ns_observe,
        ));
        table.row(vec![
            n_users.to_string(),
            l.to_string(),
            n_decisions.to_string(),
            format!("{ns_decision:.0}"),
            format!("{ns_observe:.0}"),
        ]);
    }
    if !smoke {
        println!("{}", table.to_markdown());
        println!("(ns/decision should grow sub-linearly in users: dirty sets are per-user blocks)");
    }
    total_mismatches
}

/// §P2 store selector: `MMGPEI_GP_STRUCTURE=sharded` swaps the dense
/// backend for the sharded one — honored in **every** mode, including
/// `--smoke`, which is how CI's determinism gate replays the sharded
/// smoke run at two thread widths and `cmp`s the report bytes.
fn p2_structure_sharded() -> bool {
    match std::env::var("MMGPEI_GP_STRUCTURE").as_deref() {
        Err(_) | Ok("dense") => false,
        Ok("sharded") => true,
        Ok(v) => panic!("MMGPEI_GP_STRUCTURE={v:?}: expected dense|sharded"),
    }
}

/// Kronecker form of the synthetic workload's prior: ρ = 0 (independent
/// tenants) over the same shared Matérn-5/2 model gram, i.e. bitwise the
/// block-diagonal `prior_cov` that `synthetic_gp` materializes — so the
/// sharded-vs-dense gates below demand exact equality, not a tolerance.
fn kron_prior(cfg: &SyntheticConfig, problem: &Problem) -> KroneckerPrior {
    let pts: Vec<Vec<f64>> = (0..cfg.n_models).map(|m| vec![m as f64 * 0.25]).collect();
    let c = Matern52 { variance: cfg.variance, lengthscale: cfg.lengthscale }.gram(&pts);
    KroneckerPrior::new(cfg.n_users, c, 0.0, problem.prior_mean.clone()).expect("synthetic model gram is PSD")
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// §Perf P2s — the sharded block-Kronecker store (`[gp] structure =
/// "sharded"`), in three parts:
///
/// * **ρ = 0 parity gate** (every mode, incl. `--smoke`): a full serving
///   run through `NativeBackend::sharded` must reproduce the dense
///   backend's picks *and* its score fold bit for bit — independent
///   tenants make the sharded store algebraically identical to the dense
///   factor, down to the float schedule;
/// * **ρ > 0 parity gate** (every mode): on a small coupled instance the
///   Woodbury cross-term must match the dense oracle over the
///   materialized B(ρ) ⊗ C covariance to 1e-7 relative;
/// * **scaling sweep** (full runs only): tenant counts from
///   `MMGPEI_P2_USERS` (comma list, default `10000,100000`, ignored in
///   `--smoke` like every grid knob) × 16 models — bare
///   `ShardedGp::observe` at ρ = 0.25 (`scaling/ns_per_observe@u{N}x16`,
///   hard-gated **sub-quadratic** in N) and whole-backend serving
///   throughput at ρ = 0 (`throughput/decisions_per_sec@u{N}x16`). The
///   dense store is O(L²) memory and O(t²) per observe — at these sizes
///   it cannot even be constructed, which is the point.
///
/// Every divergence lands in the report as a hard-gated KPI and in the
/// returned count, which `main` turns into a non-zero exit.
fn sharded_sweep(report: &mut RunReport, smoke: bool) -> usize {
    println!("\n=== §Perf P2s: sharded block-Kronecker GP ===\n");
    let mut total_mismatches = 0usize;

    // (1) ρ = 0: bitwise dense parity over a full serving run.
    let sizes: &[(usize, usize)] = if smoke { &[(8, 8), (16, 8)] } else { &[(16, 16), (64, 16)] };
    for &(n_users, n_models) in sizes {
        let cfg = SyntheticConfig { n_users, n_models, ..Default::default() };
        report.fold_config(&format!("p2s parity n_users={n_users} n_models={n_models}"));
        let (problem, truth) = synthetic_gp(&cfg, 0x5CA1E);
        let l = problem.n_arms();
        let mut order: Vec<usize> = (0..l / 2).map(|i| (i * 7 + 3) % l).collect();
        order.sort_unstable();
        order.dedup();
        let mut picks_dense = Vec::with_capacity(order.len());
        let mut picks_sharded = Vec::with_capacity(order.len());
        let acc_dense =
            drive_backend(NativeBackend::new(&problem), &problem, &truth, &order, Some(&mut picks_dense));
        let backend = NativeBackend::sharded(&problem, kron_prior(&cfg, &problem));
        let acc_sharded = drive_backend(backend, &problem, &truth, &order, Some(&mut picks_sharded));
        let mut mismatches = picks_dense.iter().zip(&picks_sharded).filter(|(d, s)| d != s).count();
        mismatches += usize::from(acc_dense.to_bits() != acc_sharded.to_bits());
        total_mismatches += mismatches;
        report.push_kpi(
            format!("parity/sharded_vs_dense_mismatches@u{n_users}x{n_models}"),
            mismatches as f64,
            Direction::LowerIsBetter,
        );
        println!("parity(ρ=0) u{n_users}x{n_models}: {mismatches} sharded-vs-dense divergences (must be 0)");
    }

    // (2) ρ > 0: the Woodbury cross-term vs the dense oracle, rel-tol.
    {
        let (n_users, n_models, rho) = (6usize, 4usize, 0.25f64);
        report.fold_config(&format!("p2s rho-parity n_users={n_users} n_models={n_models} rho={rho}"));
        let pts: Vec<Vec<f64>> = (0..n_models).map(|m| vec![m as f64 * 0.25]).collect();
        let c = Matern52 { variance: 1.0, lengthscale: 0.8 }.gram(&pts);
        let prior = KroneckerPrior::constant_mean(n_users, c, rho, 0.1).expect("Matérn gram is PSD");
        let (mean, cov) = prior.dense_prior();
        let mut dense = Gp::new(mean, cov);
        let mut sharded = ShardedGp::new(prior);
        let n = sharded.n_arms();
        for k in 0..n / 2 {
            let x = (k * 5 + 2) % n;
            if dense.is_observed(x) {
                continue;
            }
            let z = ((k * 37 + 11) % 97) as f64 / 97.0 - 0.5;
            dense.observe(x, z);
            sharded.observe(x, z);
        }
        let mut mismatches = 0usize;
        for x in 0..n {
            let (dm, ds) = (dense.posterior_mean(x), dense.posterior_std(x));
            let (sm, ss) = (sharded.posterior_mean(x), sharded.posterior_std(x));
            if !rel_close(dm, sm, 1e-7) || !rel_close(ds, ss, 1e-7) {
                mismatches += 1;
            }
        }
        total_mismatches += mismatches;
        report.push_kpi(
            format!("parity/sharded_vs_dense_rho_mismatches@u{n_users}x{n_models}"),
            mismatches as f64,
            Direction::LowerIsBetter,
        );
        println!(
            "parity(ρ={rho}) u{n_users}x{n_models}: {mismatches}/{n} posteriors beyond 1e-7 relative (must be 0)"
        );
    }

    if smoke {
        return total_mismatches; // Scaling timings are wall-clock noise.
    }

    // (3) 10⁴–10⁶-tenant scaling: dense-infeasible sizes, sharded only.
    let grid: Vec<usize> = std::env::var("MMGPEI_P2_USERS")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|p| p.trim().replace('_', ""))
                .filter(|p| !p.is_empty())
                .map(|p| p.parse().unwrap_or_else(|_| panic!("MMGPEI_P2_USERS: bad tenant count {p:?}")))
                .collect()
        })
        .unwrap_or_else(|| vec![10_000, 100_000]);
    let n_models = 16usize;
    let pts: Vec<Vec<f64>> = (0..n_models).map(|m| vec![m as f64 * 0.25]).collect();
    let c = Matern52 { variance: 1.0, lengthscale: 0.8 }.gram(&pts);
    let mut table = Table::new(&["tenants", "L (arms)", "ns/observe (ρ=0.25)", "decisions/s (ρ=0)"]);
    let mut prev: Option<(usize, f64)> = None;
    for &n_users in &grid {
        report.fold_config(&format!("p2s n_users={n_users} n_models={n_models}"));
        // (a) Bare sharded observe with cross-tenant coupling on: every
        // observation lands on a fresh tenant, so each pays the worst
        // case — cold-shard setup plus the Woodbury capacitance refresh.
        let prior = KroneckerPrior::constant_mean(n_users, c.clone(), 0.25, 0.0).expect("Matérn gram is PSD");
        let mut gp = ShardedGp::new(prior);
        let k_obs = 2048.min(n_users);
        let stride = n_users / k_obs;
        let t0 = std::time::Instant::now();
        for k in 0..k_obs {
            let x = (k * stride) * n_models + (k % n_models);
            let z = ((k * 37 + 11) % 97) as f64 / 97.0 - 0.5;
            black_box(gp.observe(x, z));
        }
        let ns_observe = t0.elapsed().as_nanos() as f64 / k_obs as f64;
        report.push_kpi(
            format!("scaling/ns_per_observe@u{n_users}x{n_models}"),
            ns_observe,
            Direction::LowerIsBetter,
        );
        report.push_timing(TimingEntry::flat(
            format!("p2s/ns_per_observe@u{n_users}x{n_models}"),
            k_obs as u64,
            ns_observe,
        ));
        // Acceptance gate: per-observe cost must grow sub-quadratically
        // in the tenant count (per-tenant factorization makes it near
        // constant; the quadratic envelope leaves wall-clock headroom).
        if let Some((n_prev, ns_prev)) = prev {
            let ratio = n_users as f64 / n_prev as f64;
            if ratio > 1.0 && ns_observe > ns_prev * ratio * ratio {
                eprintln!(
                    "FAIL: ns/observe grew super-quadratically: {ns_prev:.0} @ u{n_prev} → {ns_observe:.0} @ u{n_users}"
                );
                total_mismatches += 1;
            }
        }
        prev = Some((n_users, ns_observe));

        // (b) Whole-backend serving throughput at ρ = 0: observe →
        // incumbent fold → dirty rescore → tree argmax per decision, on
        // the user-major membership the config path wires up.
        let prior0 = KroneckerPrior::constant_mean(n_users, c.clone(), 0.0, 0.0).expect("Matérn gram is PSD");
        let n_arms = prior0.n_arms();
        let mut backend = NativeBackend::sharded_user_major(prior0, vec![1.0; n_arms]);
        let mut selected = vec![false; n_arms];
        let mut best = vec![0.0f64; n_users];
        let dev = DeviceView::unit(0);
        // Warm decision outside the timed loop: it pays the one-time
        // full score assembly + tournament-tree build.
        let warm = backend.eirate(&best, &selected, ScoreMode::CostRate, dev);
        black_box(warm[warm.len() - 1]);
        let n_dec = 2048.min(n_users);
        let stride_d = n_users / n_dec;
        let mut acc = 0.0;
        let t0 = std::time::Instant::now();
        for k in 0..n_dec {
            let u = k * stride_d;
            let x = u * n_models + ((k + 7) % n_models);
            let z = ((k * 53 + 29) % 101) as f64 / 101.0 - 0.5;
            backend.observe(x, z);
            selected[x] = true;
            best[u] = best[u].max(z);
            let scores = backend.eirate(&best, &selected, ScoreMode::CostRate, dev);
            acc += scores[scores.len() - 1];
            black_box(backend.select_arm(&best, &selected, ScoreMode::CostRate, dev));
        }
        let elapsed = t0.elapsed();
        black_box(acc);
        let dps = n_dec as f64 / elapsed.as_secs_f64();
        report.push_kpi(
            format!("throughput/decisions_per_sec@u{n_users}x{n_models}"),
            dps,
            Direction::HigherIsBetter,
        );
        report.push_timing(TimingEntry::flat(
            format!("p2s/ns_per_decision@u{n_users}x{n_models}"),
            n_dec as u64,
            elapsed.as_nanos() as f64 / n_dec as f64,
        ));
        table.row(vec![
            n_users.to_string(),
            n_arms.to_string(),
            format!("{ns_observe:.0}"),
            format!("{dps:.0}"),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("(per-tenant shards keep ns/observe ~flat in tenants; dense O(L²) memory can't reach these sizes)");
    total_mismatches
}

/// End-to-end: decision latency inside the live coordinator.
fn coordinator_latency(report: &mut RunReport) {
    println!("\n--- live coordinator decision latency (azure, 4 devices) ---");
    let data = mmgpei::workload::azure();
    let mut rng = Rng::new(5);
    let split = data.protocol_split(&mut rng, 8);
    let (problem, truth) = data.make_problem(&split);
    for backend in ["native", "xla"] {
        let mut policy: Box<dyn mmgpei::sched::Policy> = match backend {
            "native" => Box::new(mmgpei::sched::MmGpEi::new(&problem)),
            _ => match XlaBackend::new(&problem, &default_artifact_dir()) {
                Ok(b) => Box::new(mmgpei::sched::MmGpEi::with_backend(&problem, Box::new(b))),
                Err(_) => {
                    println!("xla: skipped (build with --features xla and run `make artifacts`)");
                    continue;
                }
            },
        };
        let serve_report = mmgpei::coordinator::serve(
            &problem,
            &truth,
            policy.as_mut(),
            &mmgpei::coordinator::ServeConfig {
                n_devices: 4,
                time_scale: 0.0005,
                warm_start_per_user: 2,
                verbose: false,
            },
        );
        report.push_timing(TimingEntry::flat(
            format!("coordinator/decision_latency/{backend}"),
            serve_report.decision_latencies.len() as u64,
            serve_report.mean_decision_latency().as_nanos() as f64,
        ));
        println!(
            "{backend:>7}: mean {:?}, max {:?} over {} decisions (makespan {:?})",
            serve_report.mean_decision_latency(),
            serve_report.max_decision_latency(),
            serve_report.decision_latencies.len(),
            serve_report.makespan
        );
    }
}
