//! Figure 6 (extension) — **the service under tenant churn**: arrival/
//! departure traffic replayed through the churn scheduler stack.
//!
//! Not a paper figure: the paper freezes the tenant cohort, but the
//! service framing it opens with (and the ease.ml line of work it builds
//! on) is defined by churn. This harness measures, per policy:
//!
//! * **per-tenant regret at exit** — Eq. 2 integrated over each tenant's
//!   active window(s);
//! * **p99 join-to-first-decision latency** — virtual time from a
//!   tenant's arrival to the first dispatch of one of its arms;
//! * **ns/decision under churn** (full runs only) — scheduler overhead
//!   while the cohort turns over;
//! * **churn parity** (every mode, hard-gated): the incremental
//!   join/leave implementation (MM-GP-EI applying `user_joined`/
//!   `user_left` in place) must replay **bit-identical** schedules,
//!   regret, and join latencies to the from-scratch rebuild oracle
//!   (`ForceRebuild` + history replay at every event). Any divergence
//!   exits non-zero — with or without a checked-in baseline.
//!
//! Run: `cargo bench --bench fig6_churn`
//! CI:  `cargo bench --bench fig6_churn -- --smoke --json reports/BENCH_fig6_churn.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::run_churn_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::problem::Problem;
use mmgpei::report::{Direction, RunReport, TimingEntry};
use mmgpei::sched::{ForceRebuild, MmGpEi, Policy};
use mmgpei::sim::{simulate_churn, ChurnResult, SimConfig};
use mmgpei::workload::{churn_workload, ChurnConfig};

fn main() {
    let opts = BenchOpts::from_env_args();
    let churn_cfg = if opts.smoke {
        // Pinned CI preset (must be identical on every machine).
        ChurnConfig {
            n_users: 10,
            n_models: 6,
            initial_users: 4,
            arrival_gap: 3.0,
            sojourn: (20.0, 50.0),
            rejoin_prob: 0.5,
            rejoin_gap: 8.0,
            ..Default::default()
        }
    } else {
        ChurnConfig { n_users: 32, n_models: 8, initial_users: 10, ..Default::default() }
    };
    let seeds = opts.seeds("MMGPEI_FIG6_SEEDS", 5, 2);
    let devices: Vec<usize> = if opts.smoke { vec![2] } else { vec![2, 4] };

    let cfg = ExperimentConfig {
        name: "fig6-churn".into(),
        dataset: "synthetic".into(), // unused: churn runs its own generator
        policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
        devices: devices.clone(),
        seeds,
        threads: opts.threads(),
        churn: true,
        churn_cfg: churn_cfg.clone(),
        ..Default::default()
    };

    let mut report = RunReport::new("fig6_churn", 0, opts.smoke);
    println!(
        "=== Figure 6 (ext) — tenant churn: {} tenants ({} initial) × {} models, ρ = {}, {} seeds ===",
        churn_cfg.n_users, churn_cfg.initial_users, churn_cfg.n_models, churn_cfg.user_corr, seeds
    );

    // ------------------------------------------------------------------
    // Churn parity gate: incremental join/leave vs from-scratch rebuild.
    // ------------------------------------------------------------------
    let mut mismatches = 0usize;
    for seed in 0..seeds {
        for &m in &devices {
            let (problem, truth, schedule) = churn_workload(&churn_cfg, 0x6C0 + seed);
            let sim_cfg = SimConfig {
                n_devices: m,
                warm_start_per_user: cfg.warm_start,
                horizon: None,
                stop_at_cutoff: None,
            };
            let inc_factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
            let oracle_factory =
                |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
            let inc = simulate_churn(&problem, &truth, &schedule, &inc_factory, &sim_cfg);
            let oracle = simulate_churn(&problem, &truth, &schedule, &oracle_factory, &sim_cfg);
            assert_eq!(inc.n_rebuilds, 0, "incremental path must never rebuild");
            assert!(oracle.n_rebuilds > 0, "oracle must exercise the rebuild path");
            if !runs_bit_identical(&inc, &oracle) {
                mismatches += 1;
                eprintln!("parity FAIL: seed {seed} M{m} — incremental ≠ rebuild oracle");
            }
        }
    }
    report.push_kpi(
        "parity/churn_incremental_vs_rebuild_mismatches",
        mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!(
        "parity: {mismatches}/{} diverging (seed, devices) churn runs (must be 0)",
        seeds as usize * devices.len()
    );

    // ------------------------------------------------------------------
    // The churn sweep: per-tenant exit regret + join latency per policy.
    // ------------------------------------------------------------------
    let results = run_churn_experiment(&cfg).expect("fig6 churn sweep");
    results.push_kpis(&mut report, "churn/");
    let mut table = Table::new(&[
        "policy",
        "devices",
        "mean exit regret/tenant",
        "p99 join latency",
        "served",
        "rebuilds",
    ]);
    for cell in &results.cells {
        table.row(vec![
            cell.policy.clone(),
            cell.devices.to_string(),
            format!("{:.3}", cell.mean_exit_regret),
            if cell.p99_join_latency.is_finite() {
                format!("{:.2}", cell.p99_join_latency)
            } else {
                "n/a".into()
            },
            format!("{:.0}%", 100.0 * cell.served_fraction),
            cell.n_rebuilds.to_string(),
        ]);
    }
    println!("{}", table.to_markdown());

    // ns/decision under churn (wall clock — full runs only; smoke keeps
    // the report byte-stable).
    if !opts.smoke {
        for cell in &results.cells {
            let decisions: u64 = cell.runs.iter().map(|r| r.n_decisions as u64).sum();
            if decisions == 0 {
                continue;
            }
            let total_ns: f64 =
                cell.runs.iter().map(|r| r.decision_wall_time.as_nanos() as f64).sum();
            let ns = total_ns / decisions as f64;
            report.push_kpi(
                format!("churn/{}@M{}/ns_per_decision", cell.policy, cell.devices),
                ns,
                Direction::LowerIsBetter,
            );
            report.push_timing(TimingEntry::flat(
                format!("churn/{}@M{}/ns_per_decision", cell.policy, cell.devices),
                decisions,
                ns,
            ));
            println!(
                "{:>14}@M{}: {:.0} ns/decision over {} churn decisions",
                cell.policy, cell.devices, ns, decisions
            );
        }
    }

    println!("expected shape: MDMT's shared prior warm-starts late arrivals — lower exit regret than per-user baselines.");
    // Write the report first (the mismatch KPI is evidence worth
    // keeping), then hard-fail: churn parity is a correctness invariant.
    opts.finish(&report);
    if mismatches > 0 {
        eprintln!("FAIL: {mismatches} churn parity mismatches vs the rebuild oracle (must be 0)");
        std::process::exit(1);
    }
}

/// Bit-exact run equality: schedule, regret accounting, join latencies.
fn runs_bit_identical(a: &ChurnResult, b: &ChurnResult) -> bool {
    let obs = |r: &ChurnResult| -> Vec<(usize, usize, u64, u64)> {
        r.observations
            .iter()
            .map(|o| (o.arm, o.device, o.finish.to_bits(), o.z.to_bits()))
            .collect()
    };
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    let lat = |r: &ChurnResult| -> Vec<Option<u64>> {
        r.join_latency.iter().map(|l| l.map(f64::to_bits)).collect()
    };
    obs(a) == obs(b)
        && bits(&a.per_user_regret) == bits(&b.per_user_regret)
        && lat(a) == lat(b)
        && a.cumulative_regret.to_bits() == b.cumulative_regret.to_bits()
        && a.inst_regret == b.inst_regret
}
