//! Figure 8 (extension) — **fault-tolerant serving**: seeded device
//! crash/restart cycles, lost jobs, and straggler slowdowns replayed
//! through the unified scheduling engine, with per-job deadlines and
//! capped-backoff retries.
//!
//! Not a paper figure: the paper's model (§3) assumes devices never
//! fail and jobs always complete, but the service-provider setting it
//! motivates — preemptible cloud capacity, flaky accelerators — loses
//! devices and jobs all the time. This harness measures, per policy:
//!
//! * **cumulative regret under faults** vs the **fault-free elastic
//!   baseline** on the same seeds (`regret_vs_fault_free`, a
//!   deterministic ratio — the robustness tax);
//! * **served fraction** (abandoned arms push it below 1), **retry
//!   count**, **abandoned arms**, and **p99 recovery latency** (first
//!   failure of an arm → its successful completion);
//! * three hard gates (every mode, non-zero exit on failure):
//!   - **byte identity**: an **empty** `FaultPlan` must reproduce the
//!     fault-free `simulate_fleet` run **bit for bit** — schedule,
//!     regret bits, curve, preemption accounting — with all fault
//!     counters zero (the "fault layer costs nothing when off"
//!     invariant in executable form);
//!   - **cross-loop parity**: `coordinator::serve_fleet_deterministic`
//!     (wall-clock adapter on the engine's `MockClock`) must replay
//!     `sim::simulate_faults` (virtual clock) bit for bit under the
//!     same preemption-heavy fault trace;
//!   - **replay determinism**: the seeded plan generator and a full
//!     faulty run are bit-stable across repeated invocations.
//!
//! Run: `cargo bench --bench fig8_faults`
//! CI:  `cargo bench --bench fig8_faults -- --smoke --json reports/BENCH_fig8_faults.json`

use std::time::Duration;

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::{make_instance, run_faults_experiment, run_fleet_experiment};
use mmgpei::config::ExperimentConfig;
use mmgpei::coordinator::{serve_fleet_deterministic, FleetServeReport, ServeConfig};
use mmgpei::engine::FaultStats;
use mmgpei::problem::{DeviceFleet, FaultPlan, Problem, Truth};
use mmgpei::report::{Direction, RunReport};
use mmgpei::sched::{MmGpEi, Policy};
use mmgpei::sim::{simulate_faults, simulate_fleet, FaultResult, SimConfig, SimResult};
use mmgpei::workload::{fault_plan, fleet_schedule, FaultsConfig, FleetConfig, SyntheticConfig};

fn main() {
    let opts = BenchOpts::from_env_args();
    let (synthetic, fleet_cfg, faults_cfg) = if opts.smoke {
        // Pinned CI preset (must be identical on every machine).
        (
            SyntheticConfig { n_users: 8, n_models: 6, ..Default::default() },
            FleetConfig {
                n_devices: 4,
                initial_online: 3,
                speed_range: (0.5, 2.0),
                arrival_gap: 6.0,
                uptime: (15.0, 40.0),
                outage: (4.0, 10.0),
                horizon: 80.0,
            },
            FaultsConfig {
                mtbf: 20.0,
                mean_downtime: 4.0,
                job_failure_gap: 10.0,
                straggler_gap: 15.0,
                horizon: 80.0,
                ..Default::default()
            },
        )
    } else {
        (
            SyntheticConfig { n_users: 16, n_models: 10, ..Default::default() },
            FleetConfig { n_devices: 6, initial_online: 4, ..Default::default() },
            FaultsConfig::default(),
        )
    };
    let seeds = opts.seeds("MMGPEI_FIG8_SEEDS", 5, 2);

    let cfg = ExperimentConfig {
        name: "fig8-faults".into(),
        dataset: "synthetic".into(),
        policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
        devices: vec![1], // unused: the fleet is the device dimension
        seeds,
        threads: opts.threads(),
        synthetic: synthetic.clone(),
        fleet: true,
        fleet_cfg: fleet_cfg.clone(),
        faults: true,
        faults_cfg: faults_cfg.clone(),
        ..Default::default()
    };

    let mut report = RunReport::new("fig8_faults", 0, opts.smoke);
    // Per-seed (instance, fleet, plan): built once, shared by every gate
    // (the sweep re-derives them inside `run_faults_experiment`,
    // identically seeded).
    let instances: Vec<(Problem, Truth, DeviceFleet, FaultPlan)> = (0..seeds)
        .map(|seed| {
            let (problem, truth) = make_instance(&cfg, seed).expect("instance");
            let fleet = fleet_schedule(&fleet_cfg, 0xF1EE7 + seed);
            let plan = fault_plan(&faults_cfg, fleet.n_devices(), 0xFA17 + seed);
            (problem, truth, fleet, plan)
        })
        .collect();
    let n_events: usize = instances.iter().map(|(_, _, _, pl)| pl.events().len()).sum();
    println!(
        "=== Figure 8 (ext) — faults: mtbf={} downtime={} job_failure_gap={} straggler_gap={}, \
         {} devices, {} seeds, {} planned fault events ===",
        faults_cfg.mtbf,
        faults_cfg.mean_downtime,
        faults_cfg.job_failure_gap,
        faults_cfg.straggler_gap,
        fleet_cfg.n_devices,
        seeds,
        n_events
    );

    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let sim_cfg = |fleet: &DeviceFleet| SimConfig {
        n_devices: fleet.n_devices(),
        warm_start_per_user: cfg.warm_start,
        horizon: None,
        stop_at_cutoff: None,
    };

    // ------------------------------------------------------------------
    // Gate 1 — byte identity: an empty fault plan must reproduce the
    // fault-free fleet run bit for bit, with every fault counter zero.
    // ------------------------------------------------------------------
    let empty = FaultPlan::empty();
    let mut identity_mismatches = 0usize;
    for (seed, (problem, truth, fleet, _)) in instances.iter().enumerate() {
        let sc = sim_cfg(fleet);
        let fault_free = simulate_fleet(problem, truth, fleet, &factory, &sc);
        let no_faults = simulate_faults(problem, truth, fleet, &empty, &factory, &sc);
        if !sim_runs_bit_identical(&fault_free.sim, &no_faults.fleet.sim)
            || fault_free.n_preemptions != no_faults.fleet.n_preemptions
            || fault_free.requeue_latency != no_faults.fleet.requeue_latency
            || fault_free.n_rebuilds != no_faults.fleet.n_rebuilds
            || no_faults.fault_stats != FaultStats::default()
            || no_faults.served_fraction != 1.0
        {
            identity_mismatches += 1;
            eprintln!("byte-identity FAIL: seed {seed} — empty plan ≠ fault-free run");
        }
    }
    report.push_kpi(
        "parity/empty_plan_vs_fault_free_mismatches",
        identity_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("byte identity: {identity_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // Gate 2 — cross-loop parity: the wall-clock fleet adapter on the
    // deterministic MockClock must replay the virtual-clock fault
    // simulator bit for bit under the seeded preemption-heavy plan.
    // ------------------------------------------------------------------
    let mut parity_mismatches = 0usize;
    for (seed, (problem, truth, fleet, plan)) in instances.iter().enumerate() {
        let sc = sim_cfg(fleet);
        let sim = simulate_faults(problem, truth, fleet, plan, &factory, &sc);
        let serve_cfg = ServeConfig {
            n_devices: fleet.n_devices(),
            time_scale: 1.0, // wall seconds = cost units: directly comparable
            warm_start_per_user: cfg.warm_start,
            verbose: false,
        };
        let served =
            serve_fleet_deterministic(problem, truth, fleet, Some(plan), &factory, &serve_cfg);
        if !faulty_runs_match(&sim, &served) {
            parity_mismatches += 1;
            eprintln!("cross-loop parity FAIL: seed {seed} — serve_fleet_deterministic ≠ simulate_faults");
        }
    }
    report.push_kpi(
        "parity/serve_fleet_vs_simulate_faults_mismatches",
        parity_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("cross-loop parity: {parity_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // Gate 3 — replay determinism: the plan generator and a full faulty
    // run are bit-stable across invocations of the same seed.
    // ------------------------------------------------------------------
    let mut replay_mismatches = 0usize;
    for (seed, (problem, truth, fleet, plan)) in instances.iter().enumerate() {
        let regen = fault_plan(&faults_cfg, fleet.n_devices(), 0xFA17 + seed as u64);
        let sc = sim_cfg(fleet);
        let a = simulate_faults(problem, truth, fleet, plan, &factory, &sc);
        let b = simulate_faults(problem, truth, fleet, plan, &factory, &sc);
        if regen != *plan
            || !sim_runs_bit_identical(&a.fleet.sim, &b.fleet.sim)
            || a.fault_stats != b.fault_stats
            || a.served_fraction.to_bits() != b.served_fraction.to_bits()
        {
            replay_mismatches += 1;
            eprintln!("replay determinism FAIL: seed {seed} — same seed, different run");
        }
    }
    report.push_kpi(
        "parity/fault_replay_mismatches",
        replay_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("replay determinism: {replay_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // The faults sweep + the fault-free control on the same seeds.
    // ------------------------------------------------------------------
    let results = run_faults_experiment(&cfg).expect("fig8 faults sweep");
    results.push_kpis(&mut report, "faults/");
    let baseline_cfg = ExperimentConfig { faults: false, ..cfg.clone() };
    let baseline = run_fleet_experiment(&baseline_cfg).expect("fig8 fault-free baseline");
    let mut table = Table::new(&[
        "policy",
        "faulty regret (mean±σ)",
        "fault-free regret",
        "ratio",
        "served",
        "retries",
        "abandoned",
        "p99 recovery",
    ]);
    for cell in &results.cells {
        let base = baseline
            .cell(&cell.policy)
            .map(|b| b.cumulative.0)
            .unwrap_or(f64::NAN);
        let ratio = if base > 0.0 { cell.cumulative.0 / base } else { f64::NAN };
        report.push_kpi(
            format!("faults/{}@D{}/regret_vs_fault_free", cell.policy, fleet_cfg.n_devices),
            ratio,
            Direction::LowerIsBetter,
        );
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{base:.2}"),
            if ratio.is_finite() { format!("{ratio:.2}×") } else { "n/a".into() },
            format!("{:.0}%", 100.0 * cell.served_fraction),
            cell.n_retries.to_string(),
            cell.n_abandoned.to_string(),
            if cell.p99_recovery_latency.is_finite() {
                format!("{:.2}", cell.p99_recovery_latency)
            } else {
                "n/a".into()
            },
        ]);
    }
    println!("{}", table.to_markdown());

    println!(
        "expected shape: faults cost regret (lost completions + retry backoff + downtime) over \
         the fault-free elastic baseline; the retry path keeps the served fraction near 1, and \
         MDMT's shared prior keeps the robustness tax smallest."
    );
    // Write the report first (the mismatch KPIs are evidence worth
    // keeping), then hard-fail: all three parities are correctness
    // invariants of the fault layer.
    opts.finish(&report);
    if identity_mismatches > 0 || parity_mismatches > 0 || replay_mismatches > 0 {
        eprintln!(
            "FAIL: {identity_mismatches} byte-identity + {parity_mismatches} cross-loop-parity + \
             {replay_mismatches} replay-determinism mismatches (must be 0)"
        );
        std::process::exit(1);
    }
}

/// Bit-exact run equality: schedule, regret accounting, curve.
fn sim_runs_bit_identical(a: &SimResult, b: &SimResult) -> bool {
    let obs = |r: &SimResult| -> Vec<(usize, usize, u64, u64, u64)> {
        r.observations
            .iter()
            .map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits(), o.z.to_bits()))
            .collect()
    };
    obs(a) == obs(b)
        && a.cumulative_regret.to_bits() == b.cumulative_regret.to_bits()
        && a.makespan.to_bits() == b.makespan.to_bits()
        && a.inst_regret == b.inst_regret
}

/// Cross-loop equality between the virtual-clock fault simulator and the
/// wall-semantics fleet adapter at `time_scale = 1.0`: the served
/// schedule (through the same `Duration` conversion both reports use),
/// the regret curve, the fault counters, and the served fraction.
fn faulty_runs_match(sim: &FaultResult, served: &FleetServeReport) -> bool {
    let sim_jobs: Vec<(usize, usize, Duration, Duration)> = sim
        .fleet
        .sim
        .observations
        .iter()
        .map(|o| {
            (
                o.arm,
                o.device,
                Duration::from_secs_f64(o.start.max(0.0)),
                Duration::from_secs_f64(o.finish.max(0.0)),
            )
        })
        .collect();
    let serve_jobs: Vec<(usize, usize, Duration, Duration)> =
        served.jobs.iter().map(|j| (j.arm, j.device, j.start, j.finish)).collect();
    sim_jobs == serve_jobs
        && sim.fleet.sim.inst_regret == served.inst_regret
        && Duration::from_secs_f64(sim.fleet.sim.makespan.max(0.0)) == served.makespan
        && sim.fleet.n_preemptions == served.n_preemptions
        && sim.fleet.n_rebuilds == served.n_rebuilds
        && sim.fault_stats == served.fault_stats
        && sim.served_fraction.to_bits() == served.served_fraction.to_bits()
}
