//! Figure 5 — "Speedup of using Multiple Devices for Our Approach on
//! Synthetic Data".
//!
//! The paper's synthetic protocol: 50 users × 50 models, zero-mean GP
//! with Matérn ν = 5/2 covariance, independent sample per user, shifted
//! to be non-negative; measure the average time for instantaneous regret
//! to hit the 0.01 cutoff while sweeping the device count; 5 repeats.
//! Expected shape: near-linear drop in convergence time.
//!
//! Full-size run is a few minutes; scale down with
//! `MMGPEI_FIG5_USERS/MODELS/SEEDS`. `--smoke` presets a 16×12 instance
//! with 2 repeats over M ∈ {1, 2, 4}.
//!
//! Run: `cargo bench --bench fig5_speedup`
//! CI:  `cargo bench --bench fig5_speedup -- --smoke --json reports/BENCH_fig5_speedup.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::metrics::mean_std;
use mmgpei::pool::WorkerPool;
use mmgpei::report::{Direction, RunReport};
use mmgpei::sched::MmGpEi;
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::{synthetic_gp, SyntheticConfig};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let opts = BenchOpts::from_env_args();
    // Smoke pins the instance size and ignores the env knobs — the CI
    // preset must be identical everywhere or baselines would never match.
    let cfg = SyntheticConfig {
        n_users: if opts.smoke { 16 } else { env_usize("MMGPEI_FIG5_USERS", 50) },
        n_models: if opts.smoke { 12 } else { env_usize("MMGPEI_FIG5_MODELS", 50) },
        ..Default::default()
    };
    let repeats = opts.seeds("MMGPEI_FIG5_SEEDS", 5, 2) as usize;
    let device_counts: &[usize] = if opts.smoke { &[1, 2, 4] } else { &[1, 2, 4, 8, 16, 32] };
    let cutoff = 0.01;
    let mut report = RunReport::new("fig5_speedup", 9000, opts.smoke);
    report.fold_config(&format!(
        "fig5 synthetic n_users={} n_models={} repeats={repeats} cutoff={cutoff} devices={device_counts:?}",
        cfg.n_users, cfg.n_models
    ));
    println!(
        "=== Figure 5 — synthetic {}×{}, Matérn ν=5/2, cutoff {cutoff}, {repeats} repeats ===",
        cfg.n_users, cfg.n_models
    );
    let mut table = Table::new(&[
        "devices",
        "time to regret ≤ 0.01 (mean ± σ)",
        "speedup",
        "efficiency",
        "arms run (mean)",
    ]);
    let mut base = None;
    // Repeats are independent simulations: shard them across the worker
    // pool (fixed seed→slot mapping, merged in seed order → the report is
    // byte-identical at any MMGPEI_THREADS).
    let pool = WorkerPool::new(opts.threads());
    for &m in device_counts {
        let per_seed = pool.map_indexed(repeats, |seed| {
            let (problem, truth) = synthetic_gp(&cfg, 9000 + seed as u64);
            let mut policy = MmGpEi::new(&problem);
            let r = simulate(
                &problem,
                &truth,
                &mut policy,
                // stop_at_cutoff: Figure 5 only measures the hitting
                // time, so the tail of the schedule is skipped.
                &SimConfig {
                    n_devices: m,
                    warm_start_per_user: 2,
                    horizon: None,
                    stop_at_cutoff: Some(cutoff),
                },
            );
            let t_hit = r.time_to(cutoff).expect("cutoff reached");
            // Count how many arms had been *dispatched* by the cutoff time
            // (the exploration cost of convergence).
            let dispatched = r.observations.iter().filter(|o| o.start <= t_hit).count() as f64;
            (t_hit, dispatched)
        });
        let times: Vec<f64> = per_seed.iter().map(|&(t, _)| t).collect();
        let arms_run: Vec<f64> = per_seed.iter().map(|&(_, a)| a).collect();
        let (mean, std) = mean_std(&times);
        let b = *base.get_or_insert(mean);
        report.push_kpi(format!("t_le_{cutoff}@M{m}"), mean, Direction::LowerIsBetter);
        report.push_kpi(format!("speedup@M{m}"), b / mean, Direction::HigherIsBetter);
        report.push_kpi(format!("arms_run@M{m}"), mean_std(&arms_run).0, Direction::LowerIsBetter);
        table.row(vec![
            m.to_string(),
            format!("{mean:.2} ± {std:.2}"),
            format!("{:.2}×", b / mean),
            format!("{:.0}%", 100.0 * b / mean / m as f64),
            format!("{:.0}", mean_std(&arms_run).0),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("paper shape: convergence time drops at a near-linear rate while M ≪ N.");
    opts.finish(&report);
}
