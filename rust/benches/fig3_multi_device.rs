//! Figure 3 — "The Impact of Multiple Devices on Our Approach".
//!
//! GP-EI-MDMT on Azure and DeepLearning with M ∈ {1, 2, 4, 8} devices;
//! the paper plots instantaneous regret vs time and observes faster
//! decay with more devices (larger effect on DeepLearning: 14 served
//! users vs Azure's 9).
//!
//! Run: `cargo bench --bench fig3_multi_device`
//! CI:  `cargo bench --bench fig3_multi_device -- --smoke --json reports/BENCH_fig3_multi_device.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::report::RunReport;

fn main() {
    let opts = BenchOpts::from_env_args();
    let seeds = opts.seeds("MMGPEI_SEEDS", 8, 2);
    let mut report = RunReport::new("fig3_multi_device", 0, opts.smoke);
    for dataset in ["azure", "deeplearning"] {
        let cfg = ExperimentConfig {
            name: format!("fig3-{dataset}"),
            dataset: dataset.into(),
            policies: vec!["mdmt".into()],
            devices: vec![1, 2, 4, 8],
            seeds,
            // Seed-sweep pool width; byte-identical output at any value.
            threads: opts.threads(),
            ..Default::default()
        };
        let res = run_experiment(&cfg).expect("fig3 sweep");
        res.push_kpis(&mut report, &format!("{dataset}/"), &[0.05, 0.01]);
        println!("\n=== Figure 3 [{dataset}] — MDMT × devices, {} seeds ===", cfg.seeds);
        let mut table = Table::new(&[
            "devices",
            "cumulative regret",
            "t: regret ≤ 0.05",
            "t: regret ≤ 0.01",
            "makespan",
        ]);
        for cell in &res.cells {
            let tt = |cut: f64| {
                let hits: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
                if hits.is_empty() { f64::NAN } else { mmgpei::metrics::mean_std(&hits).0 }
            };
            let mk =
                mmgpei::metrics::mean_std(&cell.runs.iter().map(|r| r.makespan).collect::<Vec<_>>())
                    .0;
            table.row(vec![
                cell.devices.to_string(),
                format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
                format!("{:.2}", tt(0.05)),
                format!("{:.2}", tt(0.01)),
                format!("{mk:.1}"),
            ]);
        }
        println!("{}", table.to_markdown());
    }
    println!("\npaper shape: regret decays strictly faster as devices double; larger effect");
    println!("on DeepLearning (14 users) than Azure (9 users).");
    opts.finish(&report);
}
