//! Figure 7 (extension) — **elastic heterogeneous device fleets**: mixed
//! device speeds plus spot-style availability churn replayed through the
//! unified scheduling engine.
//!
//! Not a paper figure: the paper's model (§3) fixes `M` identical
//! always-on devices, but the service-provider setting it motivates —
//! mixed GPU generations, preemptible capacity — is an elastic fleet.
//! This harness measures, per policy:
//!
//! * **cumulative regret** on the elastic fleet vs a **unit-speed
//!   always-on fleet of equal aggregate capacity** (`round(Σ s_d)`
//!   devices) — the price of elasticity (`regret_vs_unit_capacity`,
//!   a deterministic ratio);
//! * **preemption count** and **p99 requeue latency** — how often jobs
//!   are cancelled by departing devices and how long the requeued
//!   decisions wait;
//! * **ns/decision** under fleet churn (full runs only);
//! * a **device-aware vs device-blind** KPI pair: the same classed fleet
//!   with per-(arm, device-class) true costs, scheduled once by
//!   `mdmt-device` (scores `EI/(c(x, class_d)/s_d)` for the asking
//!   device) and once by plain `mdmt` (device-blind scores) —
//!   `fleet/device_{aware,blind}@F*/cumulative_regret`;
//! * three hard gates (every mode, non-zero exit on failure):
//!   - **unit parity**: a unit-speed always-on fleet through the engine
//!     replays the plain simulator **bit-identically** (the refactor's
//!     acceptance criterion in executable form);
//!   - **device-churn parity**: MM-GP-EI's in-place device hooks vs the
//!     `ForceRebuild` from-scratch oracle — both device-blind and
//!     device-aware (per-device score invalidation in the hooks) —
//!     bit-identical schedules and regret;
//!   - **device-aware degeneration**: on a uniform unit-speed fleet with
//!     no cost model, `mdmt-device` replays `mdmt` bit for bit
//!     (`EI/(c/1.0)` is bitwise `EI/c`).
//!
//! Run: `cargo bench --bench fig7_elastic`
//! CI:  `cargo bench --bench fig7_elastic -- --smoke --json reports/BENCH_fig7_elastic.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::{make_instance, run_fleet_experiment};
use mmgpei::config::ExperimentConfig;
use mmgpei::problem::{CostModel, DeviceFleet, PerClassCost, Problem, Truth};
use mmgpei::report::{Direction, RunReport, TimingEntry};
use mmgpei::sched::{ForceRebuild, MmGpEi, Policy};
use mmgpei::sim::{simulate, simulate_fleet, simulate_fleet_with_cost_model, SimConfig, SimResult};
use mmgpei::workload::{fleet_schedule, round_robin_classes, FleetConfig, SyntheticConfig};

fn main() {
    let opts = BenchOpts::from_env_args();
    let (synthetic, fleet_cfg) = if opts.smoke {
        // Pinned CI preset (must be identical on every machine).
        (
            SyntheticConfig { n_users: 8, n_models: 6, ..Default::default() },
            FleetConfig {
                n_devices: 4,
                initial_online: 3,
                speed_range: (0.5, 2.0),
                arrival_gap: 6.0,
                uptime: (15.0, 40.0),
                outage: (4.0, 10.0),
                horizon: 80.0,
            },
        )
    } else {
        (
            SyntheticConfig { n_users: 16, n_models: 10, ..Default::default() },
            FleetConfig { n_devices: 6, initial_online: 4, ..Default::default() },
        )
    };
    let seeds = opts.seeds("MMGPEI_FIG7_SEEDS", 5, 2);

    let cfg = ExperimentConfig {
        name: "fig7-elastic".into(),
        dataset: "synthetic".into(),
        policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
        devices: vec![1], // unused: the fleet is the device dimension
        seeds,
        threads: opts.threads(),
        synthetic: synthetic.clone(),
        fleet: true,
        fleet_cfg: fleet_cfg.clone(),
        ..Default::default()
    };

    let mut report = RunReport::new("fig7_elastic", 0, opts.smoke);
    // Per-seed (instance, fleet): built once, shared by both parity
    // gates and the unit-capacity control (the sweep itself re-derives
    // them inside `run_fleet_experiment`, identically seeded).
    let instances: Vec<(Problem, Truth, DeviceFleet)> = (0..seeds)
        .map(|seed| {
            let (problem, truth) = make_instance(&cfg, seed).expect("instance");
            let fleet = fleet_schedule(&fleet_cfg, 0xF1EE7 + seed);
            (problem, truth, fleet)
        })
        .collect();
    println!(
        "=== Figure 7 (ext) — elastic fleet: {} devices ({} at t=0), speeds [{}, {}), {} seeds ===",
        fleet_cfg.n_devices,
        fleet_cfg.initial_online,
        fleet_cfg.speed_range.0,
        fleet_cfg.speed_range.1,
        seeds
    );

    // ------------------------------------------------------------------
    // Gate 1 — unit parity: a unit-speed always-on fleet through the
    // engine must replay the plain simulator bit for bit.
    // ------------------------------------------------------------------
    let mut unit_mismatches = 0usize;
    for (seed, (problem, truth, _)) in instances.iter().enumerate() {
        let sim_cfg = SimConfig { n_devices: 2, ..Default::default() };
        let mut pol = MmGpEi::new(problem);
        let plain = simulate(problem, truth, &mut pol, &sim_cfg);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let unit = simulate_fleet(problem, truth, &DeviceFleet::uniform(2), &factory, &sim_cfg);
        if unit.n_preemptions != 0
            || unit.n_rebuilds != 0
            || !sim_runs_bit_identical(&plain, &unit.sim)
        {
            unit_mismatches += 1;
            eprintln!("unit parity FAIL: seed {seed} — unit fleet ≠ plain simulator");
        }
    }
    report.push_kpi(
        "parity/unit_fleet_vs_simulate_mismatches",
        unit_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("unit parity: {unit_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // Gate 2 — device-churn parity: in-place device hooks vs the
    // from-scratch rebuild oracle over the elastic fleet, device-blind
    // AND device-aware (the latter exercises the per-device score
    // invalidation the hooks perform under `ScoreMode::DeviceRate`).
    // ------------------------------------------------------------------
    let mut churn_mismatches = 0usize;
    for (seed, (problem, truth, fleet)) in instances.iter().enumerate() {
        let sim_cfg = SimConfig {
            n_devices: fleet.n_devices(),
            warm_start_per_user: cfg.warm_start,
            horizon: None,
            stop_at_cutoff: None,
        };
        let inc = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let oracle = |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
        let a = simulate_fleet(problem, truth, fleet, &inc, &sim_cfg);
        let b = simulate_fleet(problem, truth, fleet, &oracle, &sim_cfg);
        assert_eq!(a.n_rebuilds, 0, "in-place path must never rebuild");
        if a.n_preemptions != b.n_preemptions || !sim_runs_bit_identical(&a.sim, &b.sim) {
            churn_mismatches += 1;
            eprintln!("device-churn parity FAIL: seed {seed} — in-place ≠ rebuild oracle");
        }
        // Device-aware arm: same elastic fleet, two device classes with a
        // per-class cost table; the in-place hooks must invalidate the
        // per-device score cache exactly like a from-scratch rebuild.
        let model = PerClassCost::from_problem(problem, vec![1.0, 1.75], vec![f64::INFINITY; 2]);
        let classed = fleet.clone().with_classes(round_robin_classes(fleet.n_devices(), 2));
        let inc_dev =
            |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::with_cost_model(p, &model)) };
        let oracle_dev = |p: &Problem| -> Box<dyn Policy> {
            Box::new(ForceRebuild(MmGpEi::with_cost_model(p, &model)))
        };
        let some_model = Some(&model as &dyn CostModel);
        let da = simulate_fleet_with_cost_model(problem, truth, &classed, &inc_dev, &sim_cfg, some_model);
        let db =
            simulate_fleet_with_cost_model(problem, truth, &classed, &oracle_dev, &sim_cfg, some_model);
        assert_eq!(da.n_rebuilds, 0, "device-aware in-place path must never rebuild");
        if da.n_preemptions != db.n_preemptions || !sim_runs_bit_identical(&da.sim, &db.sim) {
            churn_mismatches += 1;
            eprintln!("device-churn parity FAIL: seed {seed} — device-aware in-place ≠ rebuild oracle");
        }
    }
    report.push_kpi(
        "parity/device_churn_inplace_vs_rebuild_mismatches",
        churn_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("device-churn parity: {churn_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // Gate 3 — device-aware degeneration: on a uniform unit-speed fleet
    // with no cost model, `mdmt-device` must replay `mdmt` bit for bit
    // (DeviceRate at s_d = 1.0 over one class is bitwise CostRate).
    // ------------------------------------------------------------------
    let mut degen_mismatches = 0usize;
    for (seed, (problem, truth, _)) in instances.iter().enumerate() {
        let sim_cfg = SimConfig { n_devices: 3, ..Default::default() };
        let unit = DeviceFleet::uniform(3);
        let blind = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let aware = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::device_aware(p)) };
        let a = simulate_fleet(problem, truth, &unit, &blind, &sim_cfg);
        let b = simulate_fleet(problem, truth, &unit, &aware, &sim_cfg);
        if !sim_runs_bit_identical(&a.sim, &b.sim) {
            degen_mismatches += 1;
            eprintln!("degeneration parity FAIL: seed {seed} — mdmt-device ≠ mdmt on unit fleet");
        }
    }
    report.push_kpi(
        "parity/device_aware_vs_blind_unit_fleet_mismatches",
        degen_mismatches as f64,
        Direction::LowerIsBetter,
    );
    println!("device-aware degeneration: {degen_mismatches}/{seeds} diverging seeds (must be 0)");

    // ------------------------------------------------------------------
    // The fleet sweep + the equal-aggregate-capacity control.
    // ------------------------------------------------------------------
    let results = run_fleet_experiment(&cfg).expect("fig7 fleet sweep");
    results.push_kpis(&mut report, "fleet/");
    let mut table = Table::new(&[
        "policy",
        "elastic regret (mean±σ)",
        "unit-capacity regret",
        "ratio",
        "preemptions",
        "p99 requeue latency",
        "rebuilds",
    ]);
    for cell in &results.cells {
        // Control: unit-speed always-on fleet of round(Σ s_d) devices,
        // same instances, same policy — the paper's setting at matched
        // aggregate capacity.
        let mut unit_cums = Vec::with_capacity(seeds as usize);
        for (seed, (problem, truth, fleet)) in instances.iter().enumerate() {
            let m_eq = (fleet.total_speed().round().max(1.0)) as usize;
            let policy_pool = mmgpei::pool::WorkerPool::new(1);
            let mut pol = mmgpei::cli::make_policy(
                &cell.policy,
                problem,
                truth,
                seed as u64,
                cfg.backend,
                &policy_pool,
                None,
            )
            .expect("policy");
            let r = simulate(
                problem,
                truth,
                pol.as_mut(),
                &SimConfig {
                    n_devices: m_eq,
                    warm_start_per_user: cfg.warm_start,
                    horizon: None,
                    stop_at_cutoff: None,
                },
            );
            unit_cums.push(r.cumulative_regret);
        }
        let unit_mean = mmgpei::metrics::mean_std(&unit_cums).0;
        let ratio = if unit_mean > 0.0 { cell.cumulative.0 / unit_mean } else { f64::NAN };
        report.push_kpi(
            format!("fleet/{}@F{}/regret_vs_unit_capacity", cell.policy, fleet_cfg.n_devices),
            ratio,
            Direction::LowerIsBetter,
        );
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{unit_mean:.2}"),
            if ratio.is_finite() { format!("{ratio:.2}×") } else { "n/a".into() },
            cell.n_preemptions.to_string(),
            if cell.p99_requeue_latency.is_finite() {
                format!("{:.2}", cell.p99_requeue_latency)
            } else {
                "n/a".into()
            },
            cell.n_rebuilds.to_string(),
        ]);
    }
    println!("{}", table.to_markdown());

    // ------------------------------------------------------------------
    // Device-aware vs device-blind: the same classed fleet, the same
    // per-(arm, device-class) true costs — the only difference is whether
    // the policy's scores see the asking device. Lower device-aware
    // regret is the payoff of the device-aware scheduling API.
    // ------------------------------------------------------------------
    let mut aware_cums = Vec::with_capacity(seeds as usize);
    let mut blind_cums = Vec::with_capacity(seeds as usize);
    for (problem, truth, fleet) in &instances {
        let sim_cfg = SimConfig {
            n_devices: fleet.n_devices(),
            warm_start_per_user: cfg.warm_start,
            horizon: None,
            stop_at_cutoff: None,
        };
        let model = PerClassCost::from_problem(problem, vec![1.0, 1.75], vec![f64::INFINITY; 2]);
        let classed = fleet.clone().with_classes(round_robin_classes(fleet.n_devices(), 2));
        let some_model = Some(&model as &dyn CostModel);
        let aware =
            |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::with_cost_model(p, &model)) };
        let blind = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let a = simulate_fleet_with_cost_model(problem, truth, &classed, &aware, &sim_cfg, some_model);
        let b = simulate_fleet_with_cost_model(problem, truth, &classed, &blind, &sim_cfg, some_model);
        aware_cums.push(a.sim.cumulative_regret);
        blind_cums.push(b.sim.cumulative_regret);
    }
    let aware_mean = mmgpei::metrics::mean_std(&aware_cums).0;
    let blind_mean = mmgpei::metrics::mean_std(&blind_cums).0;
    let f_n = fleet_cfg.n_devices;
    report.push_kpi(
        format!("fleet/device_aware@F{f_n}/cumulative_regret"),
        aware_mean,
        Direction::LowerIsBetter,
    );
    report.push_kpi(
        format!("fleet/device_blind@F{f_n}/cumulative_regret"),
        blind_mean,
        Direction::LowerIsBetter,
    );
    println!(
        "device-aware vs device-blind (2 classes, ×1.75 cost on class 1): \
         aware {aware_mean:.2} vs blind {blind_mean:.2} cumulative regret"
    );

    // ns/decision under fleet churn (wall clock — full runs only; smoke
    // keeps the report byte-stable).
    if !opts.smoke {
        for cell in &results.cells {
            let decisions: u64 = cell.runs.iter().map(|r| r.sim.n_decisions as u64).sum();
            if decisions == 0 {
                continue;
            }
            let total_ns: f64 =
                cell.runs.iter().map(|r| r.sim.decision_wall_time.as_nanos() as f64).sum();
            let ns = total_ns / decisions as f64;
            report.push_kpi(
                format!("fleet/{}@F{}/ns_per_decision", cell.policy, fleet_cfg.n_devices),
                ns,
                Direction::LowerIsBetter,
            );
            report.push_timing(TimingEntry::flat(
                format!("fleet/{}@F{}/ns_per_decision", cell.policy, fleet_cfg.n_devices),
                decisions,
                ns,
            ));
            println!(
                "{:>14}@F{}: {:.0} ns/decision over {} fleet decisions",
                cell.policy, fleet_cfg.n_devices, ns, decisions
            );
        }
    }

    println!(
        "expected shape: elasticity costs regret (offline windows + preemptions) at matched \
         aggregate capacity; MDMT's shared prior keeps the penalty smallest."
    );
    // Write the report first (the mismatch KPIs are evidence worth
    // keeping), then hard-fail: both parities are correctness invariants.
    opts.finish(&report);
    if unit_mismatches > 0 || churn_mismatches > 0 || degen_mismatches > 0 {
        eprintln!(
            "FAIL: {unit_mismatches} unit-parity + {churn_mismatches} device-churn-parity + \
             {degen_mismatches} device-aware-degeneration mismatches (must be 0)"
        );
        std::process::exit(1);
    }
}

/// Bit-exact run equality: schedule, regret accounting, curve.
fn sim_runs_bit_identical(a: &SimResult, b: &SimResult) -> bool {
    let obs = |r: &SimResult| -> Vec<(usize, usize, u64, u64, u64)> {
        r.observations
            .iter()
            .map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits(), o.z.to_bits()))
            .collect()
    };
    obs(a) == obs(b)
        && a.cumulative_regret.to_bits() == b.cumulative_regret.to_bits()
        && a.makespan.to_bits() == b.makespan.to_bits()
        && a.inst_regret == b.inst_regret
}
