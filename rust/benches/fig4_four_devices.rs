//! Figure 4 — "Performance of Different Model Selection Algorithms with
//! Four Computation Devices" (plus the paper's M = 8 Azure parity check).
//!
//! Three policies at M = 4 on both datasets; then Azure at M = 8, where
//! the paper observes MDMT ≈ round-robin because there are only 9 served
//! users — nothing left to prioritize.
//!
//! Run: `cargo bench --bench fig4_four_devices`
//! CI:  `cargo bench --bench fig4_four_devices -- --smoke --json reports/BENCH_fig4_four_devices.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::report::{Direction, RunReport};

fn run(dataset: &str, devices: usize, seeds: u64, threads: usize, report: &mut RunReport) {
    let cfg = ExperimentConfig {
        name: format!("fig4-{dataset}-m{devices}"),
        dataset: dataset.into(),
        policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
        devices: vec![devices],
        seeds,
        // Seed-sweep pool width; byte-identical output at any value.
        threads,
        ..Default::default()
    };
    let res = run_experiment(&cfg).expect("fig4 sweep");
    res.push_kpis(report, &format!("{dataset}/"), &[0.05, 0.01]);
    println!("\n=== Figure 4 [{dataset}, M={devices}] — {} seeds ===", cfg.seeds);
    let mut table =
        Table::new(&["policy", "cumulative regret", "t: regret ≤ 0.05", "t: regret ≤ 0.01"]);
    let mut mm = f64::NAN;
    let mut rr = f64::NAN;
    for cell in &res.cells {
        let tt = |cut: f64| {
            let hits: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
            if hits.is_empty() { f64::NAN } else { mmgpei::metrics::mean_std(&hits).0 }
        };
        if cell.policy == "mdmt" {
            mm = cell.cumulative.0;
        }
        if cell.policy == "round-robin" {
            rr = cell.cumulative.0;
        }
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{:.2}", tt(0.05)),
            format!("{:.2}", tt(0.01)),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("MDMT / round-robin cumulative-regret ratio: {:.3}", mm / rr);
    // The paper's M=4 win / M=8 saturation observation as a gated KPI.
    report.push_kpi(
        format!("{dataset}/mdmt_vs_rr_cumulative_ratio@M{devices}"),
        mm / rr,
        Direction::LowerIsBetter,
    );
}

fn main() {
    let opts = BenchOpts::from_env_args();
    let seeds = opts.seeds("MMGPEI_SEEDS", 8, 2);
    let mut report = RunReport::new("fig4_four_devices", 0, opts.smoke);
    let threads = opts.threads();
    run("azure", 4, seeds, threads, &mut report);
    run("deeplearning", 4, seeds, threads, &mut report);
    // The paper's saturation observation: M = 8 on Azure (9 users).
    run("azure", 8, seeds, threads, &mut report);
    println!("\npaper shape: MDMT wins at M=4 on Azure; ratio → ≈1 at M=8 (9 users only).");
    opts.finish(&report);
}
