//! Figure 4 — "Performance of Different Model Selection Algorithms with
//! Four Computation Devices" (plus the paper's M = 8 Azure parity check).
//!
//! Three policies at M = 4 on both datasets; then Azure at M = 8, where
//! the paper observes MDMT ≈ round-robin because there are only 9 served
//! users — nothing left to prioritize.
//!
//! Run: `cargo bench --bench fig4_four_devices`

use mmgpei::bench::Table;
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;

fn seeds() -> u64 {
    std::env::var("MMGPEI_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(8)
}

fn run(dataset: &str, devices: usize) {
    let cfg = ExperimentConfig {
        name: format!("fig4-{dataset}-m{devices}"),
        dataset: dataset.into(),
        policies: vec!["mdmt".into(), "round-robin".into(), "random".into()],
        devices: vec![devices],
        seeds: seeds(),
        ..Default::default()
    };
    let res = run_experiment(&cfg).expect("fig4 sweep");
    println!("\n=== Figure 4 [{dataset}, M={devices}] — {} seeds ===", cfg.seeds);
    let mut table =
        Table::new(&["policy", "cumulative regret", "t: regret ≤ 0.05", "t: regret ≤ 0.01"]);
    let mut mm = f64::NAN;
    let mut rr = f64::NAN;
    for cell in &res.cells {
        let tt = |cut: f64| {
            let hits: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(cut)).collect();
            if hits.is_empty() { f64::NAN } else { mmgpei::metrics::mean_std(&hits).0 }
        };
        if cell.policy == "mdmt" {
            mm = cell.cumulative.0;
        }
        if cell.policy == "round-robin" {
            rr = cell.cumulative.0;
        }
        table.row(vec![
            cell.policy.clone(),
            format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
            format!("{:.2}", tt(0.05)),
            format!("{:.2}", tt(0.01)),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("MDMT / round-robin cumulative-regret ratio: {:.3}", mm / rr);
}

fn main() {
    run("azure", 4);
    run("deeplearning", 4);
    // The paper's saturation observation: M = 8 on Azure (9 users).
    run("azure", 8);
    println!("\npaper shape: MDMT wins at M=4 on Azure; ratio → ≈1 at M=8 (9 users only).");
}
