//! Ablations A1/A2 — the design choices DESIGN.md calls out.
//!
//! * **A1 (EIrate vs EI)**: drop the cost division of Eq. 5 and rank by
//!   plain summed EI. The paper adopts EIrate from Snoek et al. [2012];
//!   with heterogeneous runtimes (VGG-16 ≈ 8× SqueezeNet) the
//!   cost-insensitive variant wastes device time on slow models.
//! * **A2 (shared GP vs independent GPs)**: keep the global EIrate
//!   allocation rule but score each arm with its owner's private GP —
//!   isolating the value of the cross-user prior (Eq. 4's sum plus the
//!   holdout-estimated covariance).
//!
//! Run: `cargo bench --bench ablations`
//! CI:  `cargo bench --bench ablations -- --smoke --json reports/BENCH_ablations.json`

use mmgpei::bench::{BenchOpts, Table};
use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::report::{Direction, RunReport};

fn main() {
    let opts = BenchOpts::from_env_args();
    let seeds = opts.seeds("MMGPEI_SEEDS", 8, 2);
    let threads = opts.threads();
    let pool = mmgpei::pool::WorkerPool::new(threads);
    let mut report = RunReport::new("ablations", 0, opts.smoke);
    for dataset in ["azure", "deeplearning"] {
        let cfg = ExperimentConfig {
            name: format!("ablations-{dataset}"),
            dataset: dataset.into(),
            policies: vec![
                "mdmt".into(),
                "mdmt-nocost".into(),
                "mdmt-indep".into(),
                "ucb-mdmt".into(),
                "ucb-round-robin".into(),
                "round-robin".into(),
                "oracle".into(),
            ],
            devices: vec![1],
            seeds,
            // Seed-sweep pool width; byte-identical output at any value.
            threads,
            ..Default::default()
        };
        let res = run_experiment(&cfg).expect("ablation sweep");
        res.push_kpis(&mut report, &format!("{dataset}/"), &[0.05]);
        println!("\n=== Ablations [{dataset}, M=1, {} seeds] ===", cfg.seeds);
        let mut table = Table::new(&[
            "variant",
            "cumulative regret",
            "t: regret ≤ 0.05",
            "vs full MDMT",
        ]);
        let full = res.cell("mdmt", 1).unwrap().cumulative.0;
        for cell in &res.cells {
            let tt: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(0.05)).collect();
            let t05 = if tt.is_empty() {
                f64::NAN
            } else {
                mmgpei::metrics::mean_std(&tt).0
            };
            table.row(vec![
                cell.policy.clone(),
                format!("{:.2} ± {:.2}", cell.cumulative.0, cell.cumulative.1),
                format!("{t05:.2}"),
                format!("{:+.1}%", 100.0 * (cell.cumulative.0 - full) / full),
            ]);
        }
        println!("{}", table.to_markdown());
    }
    println!("\nexpected: both ablations cost regret vs full MDMT; oracle lower-bounds all.");

    // A3 — Remark-1 robustness: the scheduler sees log-normally noisy
    // cost estimates ĉ(x); devices charge the true c(x). The paper
    // claims the approximation "does not degrade the performance".
    println!("\n=== Ablation A3 — cost-estimate noise (azure, M=1, {seeds} seeds) ===");
    let noise_levels: &[f64] = if opts.smoke { &[0.0, 0.3] } else { &[0.0, 0.1, 0.3, 0.5] };
    report.fold_config(&format!("a3 noise_levels={noise_levels:?} seeds={seeds}"));
    let mut table = Table::new(&["ĉ rel. noise σ", "cumulative regret", "vs exact costs"]);
    let mut exact = f64::NAN;
    for &rel_std in noise_levels {
        // Independent seeds → pool shards, merged in seed order.
        let regrets = pool.map_indexed(seeds as usize, |seed| {
            let seed = seed as u64;
            let cfg = ExperimentConfig {
                dataset: "azure".into(),
                policies: vec!["mdmt".into()],
                devices: vec![1],
                seeds: 1,
                ..Default::default()
            };
            let (problem, truth) = mmgpei::cli::make_instance(&cfg, seed).unwrap();
            let mut rng = mmgpei::prng::Rng::new(0xC057 + seed);
            let est = mmgpei::workload::noisy_cost_estimates(&problem, rel_std, &mut rng);
            let view = mmgpei::sim::with_cost_estimates(&problem, &est);
            let mut policy = mmgpei::sched::MmGpEi::new(&view);
            let r = mmgpei::sim::simulate_with_estimates(
                &problem,
                &truth,
                &mut policy,
                &mmgpei::sim::SimConfig::default(),
                Some(&est),
            );
            r.cumulative_regret
        });
        let (mean, std) = mmgpei::metrics::mean_std(&regrets);
        if rel_std == 0.0 {
            exact = mean;
        }
        report.push_kpi(format!("a3/noise_{rel_std}/cumulative_regret"), mean, Direction::LowerIsBetter);
        table.row(vec![
            format!("{rel_std:.1}"),
            format!("{mean:.2} ± {std:.2}"),
            format!("{:+.1}%", 100.0 * (mean - exact) / exact),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("expected: graceful degradation — small noise ≈ free (Remark 1).");

    // A5 — pending-arm fantasizing (kriging believer) across device
    // counts: an extension beyond the paper. With M = 1 the variants are
    // identical by construction; the benefit (if any) appears as the
    // pending set grows.
    println!("\n=== Ablation A5 — kriging-believer fantasies vs plain MDMT ===");
    let a5_devices: &[usize] = if opts.smoke { &[2] } else { &[2, 4, 8] };
    let mut table = Table::new(&["dataset", "devices", "mdmt t ≤ 0.05", "fantasy t ≤ 0.05"]);
    for dataset in ["azure", "deeplearning"] {
        for &m in a5_devices {
            let cfg = ExperimentConfig {
                dataset: dataset.into(),
                policies: vec!["mdmt".into(), "mdmt-fantasy".into()],
                devices: vec![m],
                seeds,
                threads,
                ..Default::default()
            };
            let res = run_experiment(&cfg).expect("A5 sweep");
            res.push_kpis(&mut report, &format!("a5-{dataset}/"), &[0.05]);
            let tt = |policy: &str| {
                let cell = res.cell(policy, m).unwrap();
                let hits: Vec<f64> = cell.runs.iter().filter_map(|r| r.time_to(0.05)).collect();
                mmgpei::metrics::mean_std(&hits).0
            };
            table.row(vec![
                dataset.into(),
                m.to_string(),
                format!("{:.2}", tt("mdmt")),
                format!("{:.2}", tt("mdmt-fantasy")),
            ]);
        }
    }
    println!("{}", table.to_markdown());
    println!("expected: parity at small M; fantasies help when many arms are in flight.");

    // A4 — prior (mis)specification on the synthetic workload: §4.2 says
    // hyperparameters come "from historical experiences". Compare the
    // true generative prior, hyperparameters *fitted* by maximizing the
    // marginal likelihood of 8 historical sample paths (gp::fit), and a
    // deliberately wrong prior (ℓ×4, σ²/4).
    println!("\n=== Ablation A4 — GP prior specification (synthetic 16×12, M=2) ===");
    use mmgpei::kernels::{Kernel, Matern52};
    use mmgpei::workload::{synthetic_gp, SyntheticConfig};
    let syn = SyntheticConfig { n_users: 16, n_models: 12, ..Default::default() };
    report.fold_config(&format!("a4 n_users={} n_models={} seeds={seeds}", syn.n_users, syn.n_models));
    let pts: Vec<Vec<f64>> = (0..syn.n_models).map(|m| vec![m as f64 * 0.25]).collect();
    let true_kern = Matern52 { variance: syn.variance, lengthscale: syn.lengthscale };
    // Fit hyperparameters on 8 independent historical paths (joint LML).
    let fitted_kern = {
        let gram = true_kern.gram(&pts);
        let (lchol, _) = mmgpei::linalg::cholesky_jittered(&gram, 1e-10).unwrap();
        let mut rng = mmgpei::prng::Rng::new(0xF17);
        let paths: Vec<Vec<f64>> =
            (0..8).map(|_| rng.mvn(&vec![0.0; syn.n_models], &lchol)).collect();
        let objective = |log_p: &[f64]| -> f64 {
            let k = Matern52 { variance: log_p[0].exp(), lengthscale: log_p[1].exp() };
            let g = k.gram(&pts);
            -paths.iter().map(|y| mmgpei::gp::log_marginal_likelihood(&g, y)).sum::<f64>()
        };
        let (best, _) = mmgpei::gp::nelder_mead(objective, &[0.0, 0.0], 0.5, 1e-8, 300);
        Matern52 { variance: best[0].exp(), lengthscale: best[1].exp() }
    };
    println!(
        "fitted hyperparameters: σ² = {:.3} (true {:.1}), ℓ = {:.3} (true {:.1})",
        fitted_kern.variance, syn.variance, fitted_kern.lengthscale, syn.lengthscale
    );
    let wrong_kern =
        Matern52 { variance: syn.variance / 4.0, lengthscale: syn.lengthscale * 4.0 };
    let mut table = Table::new(&["prior", "cumulative regret", "t ≤ 0.05"]);
    for (label, kpi_key, kern) in [
        ("true", "true", &true_kern),
        ("fitted (gp::fit)", "fitted", &fitted_kern),
        ("wrong (ℓ×4, σ²/4)", "wrong", &wrong_kern),
    ] {
        let per_seed = pool.map_indexed(seeds as usize, |seed| {
            let (mut problem, truth) = synthetic_gp(&syn, 0x517 + seed as u64);
            // Swap the scheduler's prior covariance for this variant's
            // block-diagonal gram (per-user independence preserved).
            let gram = kern.gram(&pts);
            let lmod = syn.n_models;
            for u in 0..syn.n_users {
                for i in 0..lmod {
                    for j in 0..lmod {
                        problem.prior_cov[(u * lmod + i, u * lmod + j)] = gram[(i, j)];
                    }
                }
            }
            let mut policy = mmgpei::sched::MmGpEi::new(&problem);
            let r = mmgpei::sim::simulate(
                &problem,
                &truth,
                &mut policy,
                &mmgpei::sim::SimConfig { n_devices: 2, ..Default::default() },
            );
            (r.cumulative_regret, r.time_to(0.05))
        });
        let regrets: Vec<f64> = per_seed.iter().map(|&(r, _)| r).collect();
        let hits: Vec<f64> = per_seed.iter().filter_map(|&(_, t)| t).collect();
        let (rm, rs) = mmgpei::metrics::mean_std(&regrets);
        let (hm, _) = mmgpei::metrics::mean_std(&hits);
        report.push_kpi(format!("a4/{kpi_key}/cumulative_regret"), rm, Direction::LowerIsBetter);
        table.row(vec![label.into(), format!("{rm:.2} ± {rs:.2}"), format!("{hm:.2}")]);
    }
    println!("{}", table.to_markdown());
    println!("expected: fitted ≈ true (the §4.2 recipe works); wrong prior costs regret.");
    opts.finish(&report);
}
