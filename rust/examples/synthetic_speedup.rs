//! The paper's Figure-5 experiment at example scale: synthetic Matérn
//! GP workload, sweep the device count, measure the time for the average
//! instantaneous regret to drop below the cutoff, and report the
//! speedup — near-linear while M ≪ N (the paper's headline property).
//!
//! Run with: `cargo run --release --example synthetic_speedup`
//! (the full 50×50 paper configuration runs in the fig5 bench:
//! `cargo bench --bench fig5_speedup`)

use mmgpei::metrics::mean_std;
use mmgpei::sched::MmGpEi;
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::{synthetic_gp, SyntheticConfig};

fn main() {
    let cfg = SyntheticConfig { n_users: 24, n_models: 16, ..Default::default() };
    let cutoff = 0.01;
    let repeats = 3;
    println!(
        "synthetic workload: {} users × {} models, Matérn ν=5/2, cutoff {}",
        cfg.n_users, cfg.n_models, cutoff
    );
    println!("\ndevices  time-to-cutoff (mean ± σ)  speedup  efficiency");
    let mut t1 = None;
    for m in [1usize, 2, 4, 8, 16] {
        let times: Vec<f64> = (0..repeats)
            .map(|seed| {
                let (problem, truth) = synthetic_gp(&cfg, 100 + seed);
                let mut policy = MmGpEi::new(&problem);
                let r = simulate(
                    &problem,
                    &truth,
                    &mut policy,
                    &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
                );
                r.time_to(cutoff).expect("all arms eventually observed")
            })
            .collect();
        let (mean, std) = mean_std(&times);
        let base = *t1.get_or_insert(mean);
        let speedup = base / mean;
        println!(
            "{m:>7}  {mean:10.2} ± {std:5.2}        {speedup:6.2}×  {:.0}%",
            100.0 * speedup / m as f64
        );
    }
    println!("\n(efficiency ≈ 100% while M ≪ N = near-linear speedup, paper §6.3)");
}
