//! A realistic AutoML-service session: the live threaded coordinator
//! serving the Azure tenants on a pool of device workers, with the
//! scheduler decisions computed by the **AOT-compiled JAX/Pallas
//! artifact through PJRT** when available (falling back to the native
//! GP if `make artifacts` has not run).
//!
//! This is the paper's Figure-1 deployment picture: N tenants, M shared
//! devices, a leader making EIrate decisions whenever a device frees.
//!
//! Run with: `cargo run --release --example azure_service`

use mmgpei::coordinator::{serve, ServeConfig};
use mmgpei::prng::Rng;
use mmgpei::runtime::{default_artifact_dir, XlaBackend};
use mmgpei::sched::MmGpEi;
use mmgpei::workload::azure;

fn main() {
    let data = azure();
    let mut rng = Rng::new(11);
    let split = data.protocol_split(&mut rng, 8);
    let (problem, truth) = data.make_problem(&split);

    // Prefer the XLA artifact backend (the production hot path); fall
    // back to the native GP when artifacts are absent.
    let artifact_dir = default_artifact_dir();
    let mut policy = match XlaBackend::new(&problem, &artifact_dir) {
        Ok(backend) => {
            println!("scoring backend: AOT XLA artifact ({artifact_dir:?})");
            MmGpEi::with_backend(&problem, Box::new(backend))
        }
        Err(e) => {
            println!("scoring backend: native rust GP (xla unavailable: {e:#})");
            MmGpEi::new(&problem)
        }
    };

    // 4 devices, 5 ms of wall clock per abstract cost unit: an Azure
    // classifier training run of cost 2.0 "takes" 10 ms here.
    let config = ServeConfig {
        n_devices: 4,
        time_scale: 0.005,
        warm_start_per_user: 2,
        verbose: true,
    };
    println!(
        "serving {} tenants over {} candidate models on {} devices\n",
        problem.n_users,
        problem.n_arms(),
        config.n_devices
    );
    let report = serve(&problem, &truth, &mut policy, &config);

    println!("\nsession complete in {:.3}s", report.makespan.as_secs_f64());
    println!(
        "decisions: {} (mean latency {:?}, max {:?})",
        report.decision_latencies.len(),
        report.mean_decision_latency(),
        report.max_decision_latency()
    );
    // Per-tenant outcome table.
    println!("\ntenant  best-found  optimal  found-at-job");
    for u in 0..problem.n_users {
        let best_found = report
            .jobs
            .iter()
            .filter(|j| problem.arm_users[j.arm].contains(&u))
            .map(|j| j.z)
            .fold(f64::NEG_INFINITY, f64::max);
        let optimal = truth.best_value(&problem, u);
        let found_at = report
            .jobs
            .iter()
            .position(|j| problem.arm_users[j.arm].contains(&u) && (j.z - optimal).abs() < 1e-12)
            .map(|i| i + 1)
            .unwrap_or(0);
        println!("{u:>6}  {best_found:10.4}  {optimal:7.4}  {found_at:12}");
    }
    assert_eq!(report.inst_regret.final_value(), 0.0, "every tenant must end optimal");
}
