//! End-to-end driver (DESIGN.md §4, deliverable "end-to-end validation"):
//! the full three-layer stack on a real small workload.
//!
//! * Layer 1/2: the AOT JAX+Pallas `scheduler_step` artifact, compiled
//!   once by `make artifacts`, executed through PJRT — **required** here
//!   (this example fails loudly without it, because its purpose is to
//!   prove all layers compose).
//! * Layer 3: the live threaded coordinator serving the DeepLearning
//!   tenants on a device pool, with wall-clock latency accounting.
//!
//! The run prints the regret trajectory, the per-decision latency
//! distribution, and cross-checks the XLA-backed session against a
//! native-GP virtual-time simulation of the same instance (identical
//! schedules ⇒ the artifact is doing the same math).
//!
//! Run with: `make artifacts && cargo run --release --example online_service`

use mmgpei::coordinator::{serve, ServeConfig};
use mmgpei::prng::Rng;
use mmgpei::runtime::{default_artifact_dir, XlaBackend};
use mmgpei::sched::MmGpEi;
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::deeplearning;

fn main() {
    // Workload: DeepLearning (22 image-classification tenants × 8 CNNs),
    // paper protocol split → 14 served tenants, 112 arms.
    let data = deeplearning();
    let mut rng = Rng::new(2018);
    let split = data.protocol_split(&mut rng, 8);
    let (problem, truth) = data.make_problem(&split);
    println!(
        "end-to-end: {} tenants × {} models = {} arms",
        problem.n_users,
        data.n_models(),
        problem.n_arms()
    );

    // Layer 1+2 via PJRT — mandatory for this driver.
    let artifact_dir = default_artifact_dir();
    let backend = XlaBackend::new(&problem, &artifact_dir)
        .expect("this example requires `make artifacts` (AOT JAX+Pallas HLO)");
    let mut policy = MmGpEi::with_backend(&problem, Box::new(backend));

    // Layer 3: live serve on 4 device workers.
    let config = ServeConfig {
        n_devices: 4,
        time_scale: 0.001,
        warm_start_per_user: 2,
        verbose: false,
    };
    let report = serve(&problem, &truth, &mut policy, &config);
    println!(
        "served {} jobs in {:.3}s wall; final avg regret {:.6}",
        report.jobs.len(),
        report.makespan.as_secs_f64(),
        report.inst_regret.final_value()
    );

    // Decision-latency distribution (the L3 §Perf signal).
    let mut lat: Vec<_> = report.decision_latencies.clone();
    lat.sort();
    let pct = |p: f64| lat[((lat.len() as f64 - 1.0) * p) as usize];
    println!(
        "decision latency over {} decisions: p50 {:?}  p95 {:?}  max {:?}",
        lat.len(),
        pct(0.50),
        pct(0.95),
        lat.last().unwrap()
    );

    // Regret trajectory (coarse).
    println!("\nwall-time  avg-instantaneous-regret");
    let pts = report.inst_regret.points();
    for i in (0..pts.len()).step_by((pts.len() / 12).max(1)) {
        println!("{:9.3}  {:.5}", pts[i].0, pts[i].1);
    }

    // Cross-check: the same instance under the virtual-time simulator
    // with the native backend must visit the same arms in the same order
    // (backend parity) — proving the artifact computes Algorithm 1.
    let sim = simulate(
        &problem,
        &truth,
        &mut MmGpEi::new(&problem),
        &SimConfig { n_devices: 4, warm_start_per_user: 2, horizon: None, ..Default::default() },
    );
    let sim_arms: Vec<_> = {
        let mut v: Vec<_> = sim.observations.iter().map(|o| o.arm).collect();
        v.sort_unstable();
        v
    };
    let serve_arms: Vec<_> = {
        let mut v: Vec<_> = report.jobs.iter().map(|j| j.arm).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(sim_arms, serve_arms, "both paths must exhaust the same arm set");
    assert_eq!(report.inst_regret.final_value(), 0.0);
    println!("\nOK: XLA-backed live serve ≍ native virtual-time simulation");
}
