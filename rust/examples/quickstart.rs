//! Quickstart: the library in ~40 lines.
//!
//! Builds the paper's Azure protocol instance (8 holdout users estimate
//! the GP prior, 9 users get served), runs MM-GP-EI against round-robin
//! on a single device, and prints the regret comparison — the essence of
//! the paper's Figure 2.
//!
//! Run with: `cargo run --release --example quickstart`

use mmgpei::prng::Rng;
use mmgpei::sched::{GpEiRoundRobin, MmGpEi};
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::azure;

fn main() {
    // 1. Workload: the Azure table (17 Kaggle users × 8 classifiers).
    let data = azure();
    println!(
        "dataset {}: {} users × {} models, per-user accuracy σ = {:.3}",
        data.name,
        data.n_users(),
        data.n_models(),
        data.mean_per_user_accuracy_std()
    );

    // 2. Paper protocol: random 8-user holdout estimates the GP prior.
    let mut rng = Rng::new(7);
    let split = data.protocol_split(&mut rng, 8);
    let (problem, truth) = data.make_problem(&split);
    println!("serving {} users over {} arms\n", problem.n_users, problem.n_arms());

    // 3. One device, two policies, same warm start (2 fastest per user).
    let cfg = SimConfig { n_devices: 1, warm_start_per_user: 2, horizon: None, ..Default::default() };
    let mm = simulate(&problem, &truth, &mut MmGpEi::new(&problem), &cfg);
    let rr = simulate(&problem, &truth, &mut GpEiRoundRobin::new(&problem), &cfg);

    // 4. Compare: cumulative "global unhappiness" and time to near-zero
    //    instantaneous regret.
    for r in [&mm, &rr] {
        println!(
            "{:<24} cumulative regret {:8.2}   regret ≤ 0.01 at t = {:7.2}",
            r.policy,
            r.cumulative_regret,
            r.time_to(0.01).unwrap_or(f64::NAN),
        );
    }
    let speedup = rr.time_to(0.01).unwrap() / mm.time_to(0.01).unwrap();
    println!("\nMM-GP-EI reaches regret ≤ 0.01 {speedup:.2}× as fast as round-robin");
}
