//! Tenant-churn invariants: the incremental join/leave path must be
//! **bit-identical** to the from-scratch rebuild oracle — for any seeded
//! join/leave/observe sequence, including leave-then-rejoin — at every
//! layer: backend scores, selections, full simulated runs, and the
//! serialized report bytes.

use mmgpei::config::ExperimentConfig;
use mmgpei::prng::Rng;
use mmgpei::problem::{ChurnEvent, ChurnEventKind, ChurnSchedule, Problem};
use mmgpei::report::RunReport;
use mmgpei::sched::{
    rescan_eirate, DeviceView, EiBackend, ForceRebuild, MmGpEi, NativeBackend, Policy, ScoreMode,
};
use mmgpei::sim::{simulate_churn, ChurnResult, SimConfig};
use mmgpei::testutil::check;
use mmgpei::workload::{churn_workload, ChurnConfig};

fn rand_churn_cfg(rng: &mut Rng) -> ChurnConfig {
    let n_users = 4 + rng.below(5);
    ChurnConfig {
        n_users,
        n_models: 3 + rng.below(3),
        initial_users: 1 + rng.below(n_users),
        arrival_gap: 1.0 + rng.uniform() * 4.0,
        sojourn: (5.0 + rng.uniform() * 5.0, 15.0 + rng.uniform() * 20.0),
        // High rejoin probability: the leave-then-rejoin case must be
        // exercised constantly, not occasionally.
        rejoin_prob: 0.75,
        rejoin_gap: 2.0 + rng.uniform() * 4.0,
        user_corr: rng.uniform() * 0.8,
        ..Default::default()
    }
}

fn bit_key(r: &ChurnResult) -> (Vec<(usize, usize, u64, u64)>, Vec<u64>, Vec<Option<u64>>, u64) {
    (
        r.observations
            .iter()
            .map(|o| (o.arm, o.device, o.finish.to_bits(), o.z.to_bits()))
            .collect(),
        r.per_user_regret.iter().map(|x| x.to_bits()).collect(),
        r.join_latency.iter().map(|l| l.map(f64::to_bits)).collect(),
        r.cumulative_regret.to_bits(),
    )
}

#[test]
fn any_seeded_churn_sequence_replays_bit_identical_to_rebuild_oracle() {
    // The acceptance property: incremental join/leave (MM-GP-EI applying
    // the hooks in place) vs the driver's from-scratch rebuild at every
    // event — same schedule bits, same per-tenant regret bits, same join
    // latencies, same curve, over randomized churn configs, seeds, and
    // device counts.
    check("churn incremental ≡ rebuild oracle", |rng| {
        let cfg = rand_churn_cfg(rng);
        let seed = rng.next_u64() % 1000;
        let devices = 1 + rng.below(4);
        let (p, t, s) = churn_workload(&cfg, seed);
        let sim_cfg = SimConfig {
            n_devices: devices,
            warm_start_per_user: 2,
            horizon: None,
            stop_at_cutoff: None,
        };
        let inc_factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let oracle_factory =
            |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
        let inc = simulate_churn(&p, &t, &s, &inc_factory, &sim_cfg);
        let oracle = simulate_churn(&p, &t, &s, &oracle_factory, &sim_cfg);
        assert_eq!(inc.n_rebuilds, 0, "hooks must be applied in place");
        assert!(oracle.n_rebuilds > 0, "oracle must rebuild");
        assert_eq!(bit_key(&inc), bit_key(&oracle), "seed {seed} M{devices}");
        assert_eq!(inc.inst_regret, oracle.inst_regret);
    });
}

#[test]
fn leave_then_rejoin_of_the_same_tenant_is_bit_exact() {
    // Deterministic pin of the rejoin case: a tenant leaves mid-run (with
    // observations on the books and correlated neighbours still active)
    // and rejoins later; the incremental path must restore its GP state
    // and incumbent bit-exactly.
    let cfg = ChurnConfig {
        n_users: 5,
        n_models: 4,
        initial_users: 5,
        user_corr: 0.5,
        ..Default::default()
    };
    let (p, t, _) = churn_workload(&cfg, 42);
    // Hand-written timeline: everyone starts; tenant 2 leaves at t=3 and
    // rejoins at t=9; tenant 0 leaves at t=9 (same instant — departure
    // applies first) and never returns; everyone out by t=40.
    let s = ChurnSchedule::new(vec![
        ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 1, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 2, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 3, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 4, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 3.0, user: 2, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 9.0, user: 0, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 9.0, user: 2, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 40.0, user: 1, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 40.0, user: 2, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 40.0, user: 3, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 40.0, user: 4, kind: ChurnEventKind::Departure },
    ]);
    let sim_cfg =
        SimConfig { n_devices: 2, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None };
    let inc_factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let oracle_factory =
        |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
    let inc = simulate_churn(&p, &t, &s, &inc_factory, &sim_cfg);
    let oracle = simulate_churn(&p, &t, &s, &oracle_factory, &sim_cfg);
    assert_eq!(bit_key(&inc), bit_key(&oracle));
    // The rejoining tenant is actually served after its return.
    let rejoin_served = inc
        .observations
        .iter()
        .any(|o| p.arm_users[o.arm][0] == 2 && o.start >= 9.0);
    assert!(rejoin_served, "tenant 2 must be served after rejoining");
}

#[test]
fn incremental_backend_scores_match_rebuilt_oracle_at_every_step() {
    // Backend-level granularity: through a random join/leave/observe
    // sequence, the incremental NativeBackend's scores and selections
    // must equal, float for float, a from-scratch GP replay scored by the
    // brute-force rescan.
    check("churn backend scores ≡ rebuilt rescan", |rng| {
        let cfg = ChurnConfig {
            n_users: 3 + rng.below(3),
            n_models: 3 + rng.below(3),
            initial_users: 1,
            user_corr: rng.uniform() * 0.8,
            ..Default::default()
        };
        let (p, t, _) = churn_workload(&cfg, rng.next_u64() % 512);
        let n = p.n_arms();
        let nu = p.n_users;

        let mut backend = NativeBackend::new(&p);
        let mut active = vec![true; nu];
        let mut selected = vec![false; n];
        let mut blocked = vec![false; n];
        let mut best = vec![0.0f64; nu];
        let mut obs_order: Vec<(usize, f64)> = Vec::new();
        let mut observed_of: Vec<Vec<usize>> = vec![Vec::new(); nu];

        let refresh_blocked = |blocked: &mut [bool], selected: &[bool], active: &[bool], p: &Problem| {
            for x in 0..p.n_arms() {
                let retired = !p.arm_users[x].iter().any(|&u| active[u]);
                blocked[x] = selected[x] || retired;
            }
        };

        for _step in 0..40 {
            match rng.below(4) {
                // Leave a random active user.
                0 => {
                    let u = rng.below(nu);
                    if active[u] {
                        active[u] = false;
                        assert!(backend.user_left(&p, u));
                        best[u] = 0.0; // dropped incumbent
                        refresh_blocked(&mut blocked, &selected, &active, &p);
                    }
                }
                // (Re)join a random inactive user.
                1 => {
                    let u = rng.below(nu);
                    if !active[u] {
                        active[u] = true;
                        assert!(backend.user_joined(&p, u));
                        // Restore the incumbent from its finished arms.
                        best[u] = observed_of[u]
                            .iter()
                            .map(|&a| t.z[a])
                            .fold(0.0f64, f64::max);
                        refresh_blocked(&mut blocked, &selected, &active, &p);
                    }
                }
                // Observe a random unselected arm of an active user.
                _ => {
                    let candidates: Vec<usize> =
                        (0..n).filter(|&x| !blocked[x]).collect();
                    if let Some(&a) = candidates.get(rng.below(candidates.len().max(1))) {
                        backend.observe(a, t.z[a]);
                        selected[a] = true;
                        blocked[a] = true;
                        obs_order.push((a, t.z[a]));
                        for &u in &p.arm_users[a] {
                            observed_of[u].push(a);
                            if active[u] {
                                best[u] = best[u].max(t.z[a]);
                            }
                        }
                    }
                }
            }
            // Oracle: fresh always-enabled GP replaying the observation
            // history, scored by the brute-force rescan.
            let mut gp = mmgpei::gp::Gp::new(p.prior_mean.clone(), p.prior_cov.clone());
            for &(a, z) in &obs_order {
                gp.observe(a, z);
            }
            let dev = DeviceView::unit(0);
            let cached = backend.eirate(&best, &blocked, ScoreMode::CostRate, dev).to_vec();
            let oracle =
                rescan_eirate(&gp, &p.arm_users, &p.cost, &best, &blocked, ScoreMode::CostRate, dev);
            for x in 0..n {
                assert!(
                    cached[x] == oracle[x],
                    "arm {x}: cached {} vs oracle {} (step {_step})",
                    cached[x],
                    oracle[x]
                );
            }
            // Selection parity (lowest-index argmax over unblocked arms).
            let scan = {
                let mut arg = None;
                let mut max = f64::NEG_INFINITY;
                for (x, &s) in oracle.iter().enumerate() {
                    if !blocked[x] && s > max {
                        max = s;
                        arg = Some(x);
                    }
                }
                arg
            };
            assert_eq!(backend.select_arm(&best, &blocked, ScoreMode::CostRate, dev), scan);
        }
    });
}

#[test]
fn churn_report_bytes_are_deterministic() {
    // Same (config, seed) → byte-identical serialized churn report: the
    // property CI's determinism/thread-invariance gate relies on for
    // BENCH_fig6_churn.json.
    let mut cfg = ExperimentConfig {
        churn: true,
        policies: vec!["mdmt".into(), "round-robin".into()],
        devices: vec![2],
        seeds: 2,
        ..Default::default()
    };
    cfg.churn_cfg =
        ChurnConfig { n_users: 6, n_models: 4, initial_users: 2, ..Default::default() };
    let render = || -> String {
        let results = mmgpei::cli::run_churn_experiment(&cfg).unwrap();
        let mut report = RunReport::new("fig6_churn", 0, true);
        report.provenance.commit = "test".into(); // pin the env-dependent field
        results.push_kpis(&mut report, "churn/");
        report.to_json_string()
    };
    let a = render();
    let b = render();
    assert_eq!(a, b, "churn smoke reports must serialize byte-identically");
    assert!(a.contains("churn/mdmt@M2/mean_exit_regret"));
    assert!(a.contains("churn/mdmt@M2/p99_join_latency"));
}

#[test]
fn departed_tenants_in_flight_completion_keeps_parity() {
    // A tenant departs while its arm is still running: the completion
    // lands after the leave. Both paths must stay bit-identical (the
    // incremental backend briefly re-enables the arm to fold the
    // observation into the shared posterior).
    let cfg = ChurnConfig {
        n_users: 4,
        n_models: 3,
        initial_users: 4,
        user_corr: 0.6,
        cost_range: (2.0, 4.0), // long jobs → departures overtake runs
        ..Default::default()
    };
    let (p, t, _) = churn_workload(&cfg, 7);
    let s = ChurnSchedule::new(vec![
        ChurnEvent { time: 0.0, user: 0, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 1, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 2, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 0.0, user: 3, kind: ChurnEventKind::Arrival },
        // Departures inside the very first wave of 2–4-unit jobs.
        ChurnEvent { time: 0.5, user: 0, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 1.0, user: 1, kind: ChurnEventKind::Departure },
        // Tenant 0 rejoins after its in-flight arm completed.
        ChurnEvent { time: 8.0, user: 0, kind: ChurnEventKind::Arrival },
        ChurnEvent { time: 30.0, user: 0, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 30.0, user: 2, kind: ChurnEventKind::Departure },
        ChurnEvent { time: 30.0, user: 3, kind: ChurnEventKind::Departure },
    ]);
    let sim_cfg =
        SimConfig { n_devices: 4, warm_start_per_user: 1, horizon: None, stop_at_cutoff: None };
    let inc_factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let oracle_factory =
        |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
    let inc = simulate_churn(&p, &t, &s, &inc_factory, &sim_cfg);
    let oracle = simulate_churn(&p, &t, &s, &oracle_factory, &sim_cfg);
    // The scenario really happens: some observation finishes after its
    // owner's departure window closed.
    let some_post_departure = inc.observations.iter().any(|o| {
        let u = p.arm_users[o.arm][0];
        (u == 0 && o.finish > 0.5 && o.start < 0.5) || (u == 1 && o.finish > 1.0 && o.start < 1.0)
    });
    assert!(some_post_departure, "schedule must produce an in-flight departure completion");
    assert_eq!(bit_key(&inc), bit_key(&oracle));
}
