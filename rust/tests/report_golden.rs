//! Report-layer contract tests: the `BENCH_*.json` schema is pinned by a
//! golden file, same-seed smoke runs must serialize byte-identically, and
//! the `compare` gate must catch an injected KPI regression end-to-end
//! (serialize → perturb → parse → compare), mirroring what CI's
//! `bench-smoke` job does with the real bench binaries.

use mmgpei::cli::run_experiment;
use mmgpei::config::ExperimentConfig;
use mmgpei::report::{compare_reports, Direction, Provenance, RunReport, TimingEntry, Tolerances};

/// The pinned schema. If this test fails because the layout changed on
/// purpose, bump `report::SCHEMA_VERSION`, update this golden, and
/// refresh `baselines/` (see baselines/README.md).
const GOLDEN: &str = r#"{
  "schema_version": 1,
  "name": "golden",
  "provenance": {
    "commit": "0000abcd",
    "seed": 7,
    "config_hash": "00000000deadbeef",
    "smoke": true
  },
  "kpis": [
    {
      "name": "azure/mdmt@M1/cumulative_regret",
      "value": 12.25,
      "better": "lower"
    },
    {
      "name": "speedup@M4",
      "value": 3.5,
      "better": "higher"
    }
  ],
  "timings": [
    {
      "name": "decision_wall",
      "iters": 64,
      "mean_ns": 1532.5,
      "p50_ns": 1532.5,
      "p95_ns": 1532.5,
      "p99_ns": 1532.5
    }
  ]
}
"#;

fn golden_report() -> RunReport {
    let mut r = RunReport {
        name: "golden".into(),
        provenance: Provenance {
            commit: "0000abcd".into(),
            seed: 7,
            config_hash: "00000000deadbeef".into(),
            smoke: true,
        },
        kpis: Vec::new(),
        // Constructed directly: push_timing would (correctly) drop
        // wall-clock entries from a smoke report, but the golden must pin
        // the timing schema too.
        timings: vec![TimingEntry::flat("decision_wall", 64, 1532.5)],
    };
    r.push_kpi("azure/mdmt@M1/cumulative_regret", 12.25, Direction::LowerIsBetter);
    r.push_kpi("speedup@M4", 3.5, Direction::HigherIsBetter);
    r
}

#[test]
fn schema_matches_golden_file() {
    assert_eq!(golden_report().to_json_string(), GOLDEN, "BENCH_*.json schema drifted — see this test's doc");
}

#[test]
fn golden_parses_back_to_the_same_report() {
    assert_eq!(RunReport::from_json_str(GOLDEN).unwrap(), golden_report());
}

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: "determinism-probe".into(),
        dataset: "synthetic".into(),
        policies: vec!["mdmt".into(), "round-robin".into()],
        devices: vec![1, 2],
        seeds: 2,
        ..Default::default()
    };
    cfg.synthetic.n_users = 6;
    cfg.synthetic.n_models = 5;
    cfg
}

/// One full smoke-report production pass: sweep → KPIs → canonical JSON.
fn produce_report() -> String {
    let cfg = tiny_cfg();
    let results = run_experiment(&cfg).expect("tiny sweep");
    let mut report = RunReport::new(cfg.name.clone(), 0, true);
    results.push_kpis(&mut report, "synthetic/", &[0.05, 0.01]);
    report.to_json_string()
}

#[test]
fn same_seed_smoke_runs_serialize_byte_identically() {
    let a = produce_report();
    let b = produce_report();
    assert_eq!(a, b, "two same-seed smoke runs must produce byte-identical reports");
    // And the report is non-trivial: it carries real KPIs.
    let parsed = RunReport::from_json_str(&a).unwrap();
    assert!(parsed.kpis.len() >= 8, "expected KPIs for 4 cells, got {}", parsed.kpis.len());
    assert!(parsed.timings.is_empty(), "smoke reports must not carry wall-clock timings");
}

#[test]
fn injected_regression_fails_compare_end_to_end() {
    let baseline_text = produce_report();
    let baseline = RunReport::from_json_str(&baseline_text).unwrap();

    // Identical candidate passes.
    let candidate = RunReport::from_json_str(&baseline_text).unwrap();
    let ok = compare_reports(&baseline, &candidate, &Tolerances::default());
    assert!(!ok.failed(), "{}", ok.render());

    // Perturb one regret KPI by +50% *in the serialized text* — the same
    // injection CI's gate self-test performs on a real BENCH_*.json.
    let kpi = baseline.kpis.iter().find(|k| k.name.ends_with("/cumulative_regret")).expect("regret KPI present");
    let old = format!("\"value\": {}", kpi.value);
    let new = format!("\"value\": {}", kpi.value * 1.5);
    let perturbed_text = baseline_text.replacen(&old, &new, 1);
    assert_ne!(perturbed_text, baseline_text, "perturbation must hit the serialized value");
    let perturbed = RunReport::from_json_str(&perturbed_text).unwrap();
    let out = compare_reports(&baseline, &perturbed, &Tolerances::default());
    assert!(out.failed(), "injected +50% regret must fail the gate:\n{}", out.render());
    assert!(out.render().contains("cumulative_regret"), "{}", out.render());
}
