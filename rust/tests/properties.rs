//! Property-based tests over scheduler/simulator invariants, using the
//! in-tree property harness (`mmgpei::testutil`) with randomized problem
//! instances. These are the "routing, batching, state" invariants the
//! coordinator relies on.

use mmgpei::prng::Rng;
use mmgpei::sched::{
    rescan_eirate, DeviceView, EiBackend, GpEiRandom, GpEiRoundRobin, MmGpEi, MmGpEiIndep,
    NativeBackend, Policy, ScoreMode, TournamentTree,
};
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::testutil::{check, gen};

fn policies(p: &mmgpei::problem::Problem, seed: u64) -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(MmGpEi::new(p)),
        Box::new(MmGpEi::cost_insensitive(p)),
        Box::new(MmGpEiIndep::new(p)),
        Box::new(GpEiRoundRobin::new(p)),
        Box::new(GpEiRandom::new(p, seed)),
    ]
}

#[test]
fn every_policy_observes_every_arm_exactly_once() {
    check("exactly-once execution", |rng| {
        let (nu, nm) = (2 + rng.below(4), 2 + rng.below(4));
        let (p, t) = gen::problem(rng, nu, nm);
        let m = 1 + rng.below(4);
        for mut pol in policies(&p, rng.next_u64()) {
            let r = simulate(
                &p,
                &t,
                pol.as_mut(),
                &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
            );
            let mut arms: Vec<_> = r.observations.iter().map(|o| o.arm).collect();
            arms.sort_unstable();
            let expect: Vec<usize> = (0..p.n_arms()).collect();
            assert_eq!(arms, expect, "policy {} must run all arms once", r.policy);
        }
    });
}

#[test]
fn devices_never_run_more_than_capacity() {
    check("device capacity", |rng| {
        let (p, t) = gen::problem(rng, 3, 4);
        let m = 1 + rng.below(5);
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
        );
        // At any completion boundary, count overlapping running intervals.
        for probe in r.observations.iter().map(|o| o.start) {
            let running = r
                .observations
                .iter()
                .filter(|o| o.start <= probe && probe < o.finish)
                .count();
            assert!(running <= m, "{} arms running at t={probe} with M={m}", running);
        }
    });
}

#[test]
fn regret_curve_is_monotone_and_nonnegative() {
    check("regret monotone", |rng| {
        let nu = 2 + rng.below(3);
        let (p, t) = gen::problem(rng, nu, 3);
        for mut pol in policies(&p, rng.next_u64()) {
            let r = simulate(
                &p,
                &t,
                pol.as_mut(),
                &SimConfig { n_devices: 2, warm_start_per_user: 1, horizon: None, ..Default::default() },
            );
            let pts = r.inst_regret.points();
            assert!(pts.iter().all(|&(_, v)| v >= -1e-12), "{}", r.policy);
            for w in pts.windows(2) {
                assert!(w[1].1 <= w[0].1 + 1e-12, "{} non-monotone", r.policy);
            }
            assert!(
                r.inst_regret.final_value().abs() < 1e-12,
                "{} must end at zero regret after exhausting arms",
                r.policy
            );
            assert!(r.cumulative_regret >= -1e-12);
        }
    });
}

#[test]
fn makespan_bounds() {
    check("makespan bounds", |rng| {
        let (p, t) = gen::problem(rng, 3, 3);
        let m = 1 + rng.below(4);
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
        );
        let total: f64 = p.cost.iter().sum();
        let max_cost = p.cost.iter().cloned().fold(0.0, f64::max);
        // Work conservation: makespan ∈ [total/M, total] and at least the
        // longest single job.
        assert!(r.makespan <= total + 1e-9);
        assert!(r.makespan >= total / m as f64 - 1e-9);
        assert!(r.makespan >= max_cost - 1e-9);
    });
}

#[test]
fn identical_seeds_reproduce_runs_exactly() {
    check("determinism", |rng| {
        let (p, t) = gen::problem(rng, 3, 3);
        let seed = rng.next_u64();
        let run = || {
            let mut pol = GpEiRandom::new(&p, seed);
            simulate(
                &p,
                &t,
                &mut pol,
                &SimConfig { n_devices: 2, warm_start_per_user: 2, horizon: None, ..Default::default() },
            )
        };
        let a = run();
        let b = run();
        let arms_a: Vec<_> = a.observations.iter().map(|o| (o.arm, o.device)).collect();
        let arms_b: Vec<_> = b.observations.iter().map(|o| (o.arm, o.device)).collect();
        assert_eq!(arms_a, arms_b);
        assert_eq!(a.cumulative_regret, b.cumulative_regret);
    });
}

#[test]
fn shared_arms_observed_once_but_credit_all_owners() {
    check("shared arms", |rng| {
        // Build a problem where one arm is shared by all users.
        let (mut p, t) = gen::problem(rng, 3, 3);
        let shared_arm = 0usize;
        for u in 1..p.n_users {
            if !p.user_arms[u].contains(&shared_arm) {
                p.user_arms[u].push(shared_arm);
            }
        }
        p.arm_users = mmgpei::problem::Problem::compute_arm_users(p.n_arms(), &p.user_arms);
        p.validate();
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: 2, warm_start_per_user: 1, horizon: None, ..Default::default() },
        );
        let count = r.observations.iter().filter(|o| o.arm == shared_arm).count();
        assert_eq!(count, 1, "shared arm must run exactly once");
    });
}

#[test]
fn warm_start_respects_selection_dedup() {
    check("warm-start dedup", |rng| {
        let (p, t) = gen::problem(rng, 4, 3);
        // Warm start larger than candidate sets → must clamp gracefully.
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: 3, warm_start_per_user: 10, horizon: None, ..Default::default() },
        );
        assert_eq!(r.observations.len(), p.n_arms());
    });
}

#[test]
fn cost_estimate_noise_preserves_invariants() {
    // Remark-1 setting: noisy ĉ(x) must not break exactly-once execution
    // or regret accounting, and durations must reflect true costs.
    check("cost-estimate noise", |rng| {
        let (p, t) = gen::problem(rng, 3, 4);
        let seed = rng.next_u64();
        let mut noise_rng = Rng::new(seed);
        let est = mmgpei::workload::noisy_cost_estimates(&p, 0.2, &mut noise_rng);
        assert!(est.iter().all(|&c| c > 0.0));
        let view = mmgpei::sim::with_cost_estimates(&p, &est);
        let mut pol = MmGpEi::new(&view);
        let r = mmgpei::sim::simulate_with_estimates(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: 2, ..Default::default() },
            Some(&est),
        );
        assert_eq!(r.observations.len(), p.n_arms());
        // Completion durations must reflect TRUE costs, not estimates.
        for o in &r.observations {
            assert!((o.finish - o.start - p.cost[o.arm]).abs() < 1e-12);
        }
        assert!(r.inst_regret.final_value().abs() < 1e-12);
    });
}

#[test]
fn cached_eirate_matches_brute_force_oracle() {
    // The dirty-set incremental scorer must be indistinguishable — float
    // for float, argmax for argmax — from a brute-force recompute, over
    // randomized membership structures (including arms shared across
    // users), observation orders, evolving incumbents, masks, and both
    // cost modes.
    check("cached eirate equals brute-force oracle", |rng| {
        let (nu, nm) = (2 + rng.below(4), 2 + rng.below(4));
        let (mut p, t) = gen::problem(rng, nu, nm);
        // Randomly share some arms across extra users so the membership
        // structure is not a clean partition.
        for _ in 0..1 + rng.below(4) {
            let u = rng.below(p.n_users);
            let a = rng.below(p.n_arms());
            if !p.user_arms[u].contains(&a) {
                p.user_arms[u].push(a);
            }
        }
        p.arm_users = mmgpei::problem::Problem::compute_arm_users(p.n_arms(), &p.user_arms);
        p.validate();

        let n = p.n_arms();
        let mut backend = NativeBackend::new(&p);
        let mut selected = vec![false; n];
        let mut best = vec![0.0f64; p.n_users];
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);

        let compare = |backend: &mut NativeBackend,
                       best: &[f64],
                       selected: &[bool],
                       mode: ScoreMode,
                       step: usize| {
            let dev = DeviceView::unit(0);
            let cached = backend.eirate(best, selected, mode, dev).to_vec();
            let oracle = rescan_eirate(backend.gp(), &p.arm_users, &p.cost, best, selected, mode, dev);
            let mut arg_c = None;
            let mut arg_o = None;
            let mut max_c = f64::NEG_INFINITY;
            let mut max_o = f64::NEG_INFINITY;
            for x in 0..cached.len() {
                assert!(
                    cached[x] == oracle[x],
                    "step {step} mode {mode:?} arm {x}: cached {} vs oracle {}",
                    cached[x],
                    oracle[x]
                );
                if cached[x] > max_c {
                    max_c = cached[x];
                    arg_c = Some(x);
                }
                if oracle[x] > max_o {
                    max_o = oracle[x];
                    arg_o = Some(x);
                }
            }
            assert_eq!(arg_c, arg_o, "step {step}: argmax must agree");
        };

        for (step, &a) in order.iter().enumerate() {
            // Score (both cost modes) before the observation; repeated
            // clean reads must also stay exact (pure cache hits).
            compare(&mut backend, &best, &selected, ScoreMode::CostRate, step);
            compare(&mut backend, &best, &selected, ScoreMode::EiOnly, step);
            compare(&mut backend, &best, &selected, ScoreMode::CostRate, step);
            backend.observe(a, t.z[a]);
            selected[a] = true;
            for &u in &p.arm_users[a] {
                best[u] = best[u].max(t.z[a]);
            }
        }
        // Exhausted state: everything masked.
        compare(&mut backend, &best, &selected, ScoreMode::CostRate, n);
        assert_eq!(
            backend.select_arm(&best, &selected, ScoreMode::CostRate, DeviceView::unit(0)),
            None,
            "exhausted → no candidate"
        );
    });
}

#[test]
fn tournament_select_matches_oracle_argmax() {
    // The tournament-tree select path must pick exactly the arm the
    // brute-force rescan's linear scan picks — value and index — over
    // randomized memberships, observation orders, incumbent evolution,
    // masks, and both cost modes.
    check("tournament select equals oracle argmax", |rng| {
        let (nu, nm) = (2 + rng.below(4), 2 + rng.below(4));
        let (mut p, t) = gen::problem(rng, nu, nm);
        for _ in 0..rng.below(4) {
            let u = rng.below(p.n_users);
            let a = rng.below(p.n_arms());
            if !p.user_arms[u].contains(&a) {
                p.user_arms[u].push(a);
            }
        }
        p.arm_users = mmgpei::problem::Problem::compute_arm_users(p.n_arms(), &p.user_arms);
        p.validate();

        let n = p.n_arms();
        let mut backend = NativeBackend::new(&p);
        let mut selected = vec![false; n];
        let mut best = vec![0.0f64; p.n_users];
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let dev = DeviceView::unit(0);
        for (step, &a) in order.iter().enumerate() {
            for mode in [ScoreMode::CostRate, ScoreMode::EiOnly] {
                let oracle =
                    rescan_eirate(backend.gp(), &p.arm_users, &p.cost, &best, &selected, mode, dev);
                let mut want = None;
                let mut max = f64::NEG_INFINITY;
                for (x, &s) in oracle.iter().enumerate() {
                    if !selected[x] && s > max {
                        max = s;
                        want = Some(x);
                    }
                }
                let got = backend.select_arm(&best, &selected, mode, dev);
                assert_eq!(got, want, "step {step} mode {mode:?}");
            }
            backend.observe(a, t.z[a]);
            selected[a] = true;
            for &u in &p.arm_users[a] {
                best[u] = best[u].max(t.z[a]);
            }
        }
        assert_eq!(backend.select_arm(&best, &selected, ScoreMode::CostRate, dev), None);
    });
}

#[test]
fn tournament_tree_matches_linear_scan_under_random_updates() {
    // Raw data-structure property: after any sequence of leaf updates and
    // invalidations (−∞ masking), the tree's (value, index) equals the
    // brute-force linear scan exactly — including quantized tie pileups
    // (NaN-free by construction; the scheduler can never score NaN).
    check("tournament tree equals linear scan", |rng| {
        let n = 1 + rng.below(96);
        let mut tree = TournamentTree::new(n);
        let mut scores = vec![f64::NEG_INFINITY; n];
        for step in 0..300 {
            let i = rng.below(n);
            let s = match rng.below(5) {
                0 => f64::NEG_INFINITY, // invalidate/mask
                1 => 0.0,               // exhausted-EI tie pileup
                2 => rng.below(6) as f64 * 0.5, // quantized ties
                _ => rng.normal().abs(),
            };
            scores[i] = s;
            tree.update(i, s);
            let mut want_i = None;
            let mut want_s = f64::NEG_INFINITY;
            for (x, &v) in scores.iter().enumerate() {
                if v > want_s {
                    want_s = v;
                    want_i = Some(x);
                }
            }
            let (got_s, got_i) = tree.best();
            assert_eq!(got_s.to_bits(), want_s.to_bits(), "step {step} value (n={n})");
            if let Some(wi) = want_i {
                assert_eq!(got_i, wi, "step {step} index (n={n})");
            } else {
                assert_eq!(got_s, f64::NEG_INFINITY, "step {step}: all masked (n={n})");
            }
        }
    });
}

#[test]
fn double_observation_is_ignored_not_corrupting() {
    // A buggy driver feeding the same completion twice must not corrupt
    // the cached scorer: the duplicate is skipped and scores stay equal
    // to the oracle's.
    check("double observe ignored", |rng| {
        let (p, t) = gen::problem(rng, 3, 3);
        let mut backend = NativeBackend::new(&p);
        let n = p.n_arms();
        let mut selected = vec![false; n];
        let mut best = vec![0.0f64; p.n_users];
        let a = rng.below(n);
        backend.observe(a, t.z[a]);
        backend.observe(a, 0.123); // duplicate, different value: ignored
        selected[a] = true;
        for &u in &p.arm_users[a] {
            best[u] = best[u].max(t.z[a]);
        }
        let dev = DeviceView::unit(0);
        let cached = backend.eirate(&best, &selected, ScoreMode::CostRate, dev).to_vec();
        let oracle =
            rescan_eirate(backend.gp(), &p.arm_users, &p.cost, &best, &selected, ScoreMode::CostRate, dev);
        for x in 0..n {
            assert!(cached[x] == oracle[x], "arm {x}: {} vs {}", cached[x], oracle[x]);
        }
        assert!((backend.gp().posterior_mean(a) - t.z[a]).abs() < 1e-12, "first value wins");
    });
}

#[test]
fn retry_backoff_is_deterministic_bounded_and_monotone() {
    // The capped-exponential backoff the fault layer schedules retries
    // with: for randomized (base, cap) knobs the sequence must be
    // deterministic, non-decreasing, bounded by the cap, and equal to
    // min(base × 2^attempt, cap) — including huge attempt counts that
    // would overflow a naive 2^attempt.
    check("retry backoff sequence", |rng| {
        let base = 0.01 + rng.uniform_in(0.0, 2.0);
        let cap = base + rng.uniform_in(0.0, 16.0);
        let retry = mmgpei::problem::RetryPolicy {
            backoff_base: base,
            backoff_cap: cap,
            ..mmgpei::problem::RetryPolicy::default()
        };
        retry.validate();
        let mut prev = 0.0f64;
        for attempt in 0..64usize {
            let d = retry.backoff(attempt);
            assert_eq!(
                d.to_bits(),
                retry.backoff(attempt).to_bits(),
                "backoff({attempt}) must be deterministic"
            );
            assert!(d >= base - 1e-15, "backoff({attempt}) = {d} below base {base}");
            assert!(d <= cap + 1e-15, "backoff({attempt}) = {d} above cap {cap}");
            assert!(d >= prev - 1e-15, "backoff must be non-decreasing: {prev} -> {d}");
            // Closed form, guarded against overflow by the cap.
            let naive = base * (2.0f64).powi(attempt.min(60) as i32);
            assert!((d - naive.min(cap)).abs() <= 1e-9 * cap.max(1.0), "backoff({attempt})");
            prev = d;
        }
        // Saturation: far past the doubling range the cap is exact.
        assert_eq!(retry.backoff(1000).to_bits(), cap.to_bits());
    });
}

#[test]
fn generated_fault_plans_are_deterministic_and_well_formed() {
    // The seeded plan generator: same (config, n_devices, seed) → the
    // same plan bit for bit, and every generated plan passes the
    // validating constructor's invariants (in-range devices, in-horizon
    // times, crash/restart alternation — `FaultPlan::new` panics inside
    // `fault_plan` otherwise, so reaching here proves them).
    check("fault plan generation", |rng| {
        let cfg = mmgpei::workload::FaultsConfig {
            mtbf: if rng.below(4) == 0 { 0.0 } else { 2.0 + rng.uniform_in(0.0, 30.0) },
            mean_downtime: 1.0 + rng.uniform_in(0.0, 8.0),
            job_failure_gap: if rng.below(4) == 0 { 0.0 } else { 2.0 + rng.uniform_in(0.0, 20.0) },
            straggler_gap: if rng.below(4) == 0 { 0.0 } else { 2.0 + rng.uniform_in(0.0, 20.0) },
            horizon: 20.0 + rng.uniform_in(0.0, 80.0),
            ..Default::default()
        };
        cfg.validate().expect("randomized knobs stay in the valid range");
        let n_devices = 1 + rng.below(6);
        let seed = rng.next_u64();
        let plan = mmgpei::workload::fault_plan(&cfg, n_devices, seed);
        let replay = mmgpei::workload::fault_plan(&cfg, n_devices, seed);
        assert_eq!(plan, replay, "same seed must regenerate the same plan");
        for e in plan.events() {
            assert!(e.time >= 0.0 && e.time < cfg.horizon, "event at {} outside horizon", e.time);
            assert!(e.device < n_devices);
        }
        if !cfg.any_channel_active() {
            assert!(plan.is_empty(), "all channels off must generate the empty plan");
        }
        // Ordered timeline (ties broken deterministically upstream).
        for w in plan.events().windows(2) {
            assert!(w[0].time <= w[1].time, "events must be time-ordered");
        }
    });
}

#[test]
fn faulty_runs_replay_bit_exactly_and_bound_retries() {
    // A full faulty simulation is deterministic per (instance, plan) and
    // its retry accounting is bounded by the policy: every scheduled
    // retry answers a failure, and no arm is both abandoned and served.
    check("faulty run determinism", |rng| {
        let (nu, nm) = (2 + rng.below(3), 2 + rng.below(3));
        let (p, t) = gen::problem(rng, nu, nm);
        let n_devices = 1 + rng.below(3);
        let fleet = mmgpei::problem::DeviceFleet::uniform(n_devices);
        let cfg = mmgpei::workload::FaultsConfig {
            mtbf: 3.0 + rng.uniform_in(0.0, 6.0),
            mean_downtime: 1.0 + rng.uniform_in(0.0, 2.0),
            job_failure_gap: 2.0 + rng.uniform_in(0.0, 4.0),
            straggler_gap: 3.0 + rng.uniform_in(0.0, 6.0),
            horizon: 40.0,
            ..Default::default()
        };
        let plan = mmgpei::workload::fault_plan(&cfg, n_devices, rng.next_u64());
        let factory = |p: &mmgpei::problem::Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let sim_cfg = SimConfig { n_devices, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None };
        let a = mmgpei::sim::simulate_faults(&p, &t, &fleet, &plan, &factory, &sim_cfg);
        let b = mmgpei::sim::simulate_faults(&p, &t, &fleet, &plan, &factory, &sim_cfg);
        let key = |r: &mmgpei::sim::FaultResult| -> Vec<(usize, usize, u64, u64)> {
            r.fleet
                .sim
                .observations
                .iter()
                .map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits()))
                .collect()
        };
        assert_eq!(key(&a), key(&b), "same plan must replay the same schedule");
        assert_eq!(a.fault_stats, b.fault_stats);
        assert_eq!(a.served_fraction.to_bits(), b.served_fraction.to_bits());

        let s = &a.fault_stats;
        let failures = s.n_job_failures + s.n_deadline_kills;
        assert_eq!(
            s.n_retries + s.n_abandoned,
            failures,
            "every failure either schedules a retry or abandons the arm"
        );
        assert!(
            s.n_abandoned * (plan.retry().max_retries + 1) <= failures,
            "abandonment requires exhausting the retry budget first"
        );
        assert!(s.n_restarts <= s.n_crashes, "restarts can never outnumber crashes");
        for &l in &s.recovery_latency {
            assert!(l.is_finite() && l >= 0.0, "recovery latency {l} must be a real delay");
        }
        // Exactly-once on the served side: no arm completes twice, and
        // the served fraction matches the observation count.
        let mut seen = vec![false; p.n_arms()];
        for o in &a.fleet.sim.observations {
            assert!(!seen[o.arm], "arm {} observed twice under faults", o.arm);
            seen[o.arm] = true;
        }
        let frac = a.fleet.sim.observations.len() as f64 / p.n_arms() as f64;
        assert_eq!(a.served_fraction.to_bits(), frac.to_bits());
    });
}

#[test]
fn more_devices_never_increase_time_to_any_cutoff() {
    // Weak-monotonicity spot check on a fixed mid-size instance (full
    // statistical version lives in the fig5 bench).
    let mut rng = Rng::new(424242);
    let (p, t) = gen::problem(&mut rng, 6, 4);
    let run = |m: usize| {
        let mut pol = MmGpEi::new(&p);
        simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
        )
    };
    let r1 = run(1);
    let r4 = run(4);
    let t1 = r1.time_to(1e-9).unwrap();
    let t4 = r4.time_to(1e-9).unwrap();
    assert!(
        t4 <= t1 * 1.2 + 1e-9,
        "4 devices should not be much slower to exhaust: {t4} vs {t1}"
    );
}
