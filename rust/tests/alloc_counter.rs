//! Counting-allocator audit of the native decision hot path.
//!
//! The fused-kernel contract (§Perf L3 iteration 3): after warm-up —
//! every buffer preallocated at construction, every capacity sized for
//! the worst case — a serving step on [`NativeBackend`] performs **zero
//! heap allocations**: not in `Gp::observe` (fused L-append + β + w +
//! μ/σ² + dirty pass), not in `eirate` (dirty rescore + incremental
//! score assembly + tournament repair), not in `select_arm` (tree root
//! read). This test installs a counting `#[global_allocator]` for this
//! test binary and asserts the count stays flat across a full serving
//! run's worth of steps.
//!
//! The counter is **thread-local**, so allocator traffic from libtest's
//! harness threads cannot leak into the measured section.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use mmgpei::gp::KroneckerPrior;
use mmgpei::kernels::{Kernel, Matern52};
use mmgpei::sched::{DeviceView, EiBackend, NativeBackend, ScoreMode};
use mmgpei::workload::{synthetic_gp, SyntheticConfig};

thread_local! {
    /// Allocations + reallocations performed by *this* thread.
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn bump() {
        // Accessing a const-initialized thread-local never allocates, so
        // this is safe to do inside the allocator itself.
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

#[test]
fn hot_path_is_allocation_free_after_warmup() {
    // A mid-size multi-tenant instance with correlated per-user blocks
    // (so observes produce non-trivial dirty sets) and heterogeneous
    // costs (so the cost-normalized assembly path runs).
    let cfg = SyntheticConfig { n_users: 12, n_models: 10, ..Default::default() };
    let (problem, truth) = synthetic_gp(&cfg, 0xA110C);
    let n = problem.n_arms();
    let mut backend = NativeBackend::new(&problem);
    let mut selected = vec![false; n];
    let mut best = vec![0.0f64; problem.n_users];

    // One serving step: observe a completion, fold incumbents, rescore,
    // and take the argmax decision — exactly what the simulator drives.
    let step = |backend: &mut NativeBackend, a: usize, selected: &mut [bool], best: &mut [f64]| {
        backend.observe(a, truth.z[a]);
        selected[a] = true;
        for &u in &problem.arm_users[a] {
            best[u] = best[u].max(truth.z[a]);
        }
        let dev = DeviceView::unit(0);
        let scores = backend.eirate(best, selected, ScoreMode::CostRate, dev);
        let fold = scores[n - 1];
        let pick = backend.select_arm(best, selected, ScoreMode::CostRate, dev);
        (fold, pick)
    };

    // Warm-up: the first eirate call bulk-builds the score buffer and
    // tree; a handful of observes exercises every buffer once. All
    // capacity is preallocated at construction, so even this phase only
    // allocates inside construction — but we don't assert that; the
    // contract starts after warm-up.
    let _ = backend.eirate(&best, &selected, ScoreMode::CostRate, DeviceView::unit(0));
    let warm = n / 4;
    for a in 0..warm {
        let _ = step(&mut backend, a, &mut selected, &mut best);
    }

    // Measured phase: a full serving run's worth of further steps, with
    // cost-mode flips (bulk tree rebuilds) included — still zero allocs.
    let before = thread_allocs();
    let mut guard = 0.0;
    for a in warm..n {
        let (fold, pick) = step(&mut backend, a, &mut selected, &mut best);
        guard += fold;
        if let Some(p) = pick {
            assert!(!selected[p]);
        }
        let scores = backend.eirate(&best, &selected, ScoreMode::EiOnly, DeviceView::unit(0));
        guard += scores[0];
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "observe/eirate/select_arm must not allocate after warm-up ({} allocations leaked; guard {guard})",
        after - before
    );
}

#[test]
fn sharded_hot_path_is_allocation_free_after_warmup() {
    // The sharded-store twin of the audit above, with the cross-tenant
    // coupling ON (ρ > 0) so every observe runs the full Woodbury path:
    // per-tenant Cholesky append, W̃ forward substitution, the global
    // (T, b̂) rank-1 fold, and the capacitance refresh — all into
    // construction-time buffers. Tenant shards are *lazily* boxed, so the
    // warm-up must touch every tenant once; after that, zero allocations.
    let (n_users, n_models, rho) = (12usize, 10usize, 0.3f64);
    let n = n_users * n_models;
    let pts: Vec<Vec<f64>> = (0..n_models).map(|m| vec![m as f64 * 0.25]).collect();
    let gram = Matern52 { variance: 1.0, lengthscale: 0.8 }.gram(&pts);
    let prior = KroneckerPrior::constant_mean(n_users, gram, rho, 0.0).expect("valid prior");
    // Heterogeneous costs so the cost-normalized assembly path runs.
    let cost: Vec<f64> = (0..n).map(|x| 0.5 + 1.5 * ((x * 7 % 11) as f64 / 11.0)).collect();
    let mut backend = NativeBackend::sharded_user_major(prior, cost);
    let mut selected = vec![false; n];
    let mut best = vec![0.0f64; n_users];
    let z_for = |a: usize| ((a * 37 + 11) % 97) as f64 / 97.0 - 0.5;

    let step = |backend: &mut NativeBackend, a: usize, selected: &mut [bool], best: &mut [f64]| {
        backend.observe(a, z_for(a));
        selected[a] = true;
        best[a / n_models] = best[a / n_models].max(z_for(a));
        let dev = DeviceView::unit(0);
        let scores = backend.eirate(best, selected, ScoreMode::CostRate, dev);
        let fold = scores[n - 1];
        let pick = backend.select_arm(best, selected, ScoreMode::CostRate, dev);
        (fold, pick)
    };

    // Warm-up: bulk score/tree build, then one observe on EVERY tenant —
    // materializing each lazy shard exactly once.
    let _ = backend.eirate(&best, &selected, ScoreMode::CostRate, DeviceView::unit(0));
    for u in 0..n_users {
        let _ = step(&mut backend, u * n_models + (u % n_models), &mut selected, &mut best);
    }

    // Measured phase: the rest of the serving run — every remaining arm
    // of every (already materialized) tenant, with mode flips included.
    let before = thread_allocs();
    let mut guard = 0.0;
    for a in 0..n {
        if selected[a] {
            continue;
        }
        let (fold, pick) = step(&mut backend, a, &mut selected, &mut best);
        guard += fold;
        if let Some(p) = pick {
            assert!(!selected[p]);
        }
        let scores = backend.eirate(&best, &selected, ScoreMode::EiOnly, DeviceView::unit(0));
        guard += scores[0];
    }
    let after = thread_allocs();
    assert_eq!(
        after - before,
        0,
        "sharded observe/eirate/select_arm must not allocate after warm-up \
         ({} allocations leaked; guard {guard})",
        after - before
    );
}

#[test]
fn counting_allocator_actually_counts() {
    // Sanity-check the instrument itself: a Vec growth must register.
    let before = thread_allocs();
    let v: Vec<u64> = (0..1024).collect();
    let after = thread_allocs();
    assert!(after > before, "allocator hook must observe Vec allocation");
    assert_eq!(v.len(), 1024);
}
