//! Theorem-2 validation: measured cumulative regret must respect the
//! paper's upper bound `O((MIU(T,K) + M)·N²/M·c̄)` and exhibit the two
//! qualitative behaviours §5.2 derives from it (convergence of average
//! regret; near-linear speedup in M while M ≪ MIU).

use mmgpei::miu::{miu_diag_bound, miu_exact, miu_total, theorem2_bound};
use mmgpei::sched::MmGpEi;
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::testutil::gen;
use mmgpei::testutil::for_all_seeds;
use mmgpei::workload::{synthetic_gp, SyntheticConfig};

/// The bound holds with the universal constant ≥ 1 (the paper absorbs a
/// constant into ≲; we check the bound expression dominates the measured
/// regret outright, which for these instances it comfortably does).
#[test]
fn measured_regret_below_theorem2_bound() {
    for_all_seeds("regret below bound", 10, |rng| {
        let (p, t) = gen::problem(rng, 4, 3);
        let m_devices = 1 + rng.below(3);
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: m_devices, warm_start_per_user: 2, horizon: None, ..Default::default() },
        );
        // MIU from the prior kernel matrix, s up to observed count.
        let n_obs = r.observations.len();
        let miu = miu_total(&p.prior_cov, n_obs, |k, s| {
            if k.rows() <= 14 {
                miu_exact(k, s)
            } else {
                miu_diag_bound(k, 1) // per-s diag bound fallback
            }
        });
        let bound = theorem2_bound(miu, p.n_users, m_devices, p.mean_optimal_cost(&t));
        assert!(
            r.cumulative_regret <= bound,
            "Regret {} exceeds Theorem-2 bound {} (MIU {miu}, M {m_devices})",
            r.cumulative_regret,
            bound
        );
    });
}

/// §5.2 "convergence to optimum": average regret Regret_T / T decays as
/// the horizon grows (models correlated, MIU sublinear).
#[test]
fn average_regret_converges() {
    let cfg = SyntheticConfig { n_users: 6, n_models: 10, ..Default::default() };
    let (p, t) = synthetic_gp(&cfg, 11);
    let run = |horizon: f64| {
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: 2, warm_start_per_user: 2, horizon: Some(horizon), ..Default::default() },
        );
        r.cumulative_regret / horizon
    };
    let short = run(10.0);
    let long = run(200.0);
    assert!(
        long < 0.5 * short,
        "average regret should decay: {short:.4} → {long:.4}"
    );
}

/// §5.2 "nearly linear speedup": the Theorem-2 bound ratio between M and
/// 2M devices approaches 2 while M ≪ MIU — and the measured cumulative
/// regret must improve with M as well (monotonicity checked broadly in
/// paper_shapes; here we check the bound's own scaling too).
#[test]
fn bound_scales_near_linearly_in_devices() {
    let miu = 50.0;
    let b1 = theorem2_bound(miu, 20, 1, 1.0);
    let b2 = theorem2_bound(miu, 20, 2, 1.0);
    let b8 = theorem2_bound(miu, 20, 8, 1.0);
    assert!((b1 / b2 - 2.0).abs() < 0.05, "speedup 1→2: {}", b1 / b2);
    assert!(b1 / b8 > 6.5, "speedup 1→8: {}", b1 / b8);
    // Once M dominates MIU the speedup saturates (paper's caveat).
    let b_large = theorem2_bound(miu, 20, 1000, 1.0);
    let b_larger = theorem2_bound(miu, 20, 2000, 1.0);
    assert!(b_large / b_larger < 1.1, "saturation when M ≫ MIU");
}

/// The σ̂ telescoping at the heart of the proof: the sum of conditional
/// stds at test time is bounded by M + MIU(T,K) (proof of Theorem 2).
#[test]
fn sigma_hat_sum_bounded_by_miu_plus_m() {
    for_all_seeds("sigma-hat telescoping", 8, |rng| {
        let (p, t) = gen::problem(rng, 3, 3);
        let n_arms = p.n_arms();
        let m_devices = 1 + rng.below(2);
        // Replay a simulated schedule, recomputing σ̂(x) = σ at dispatch
        // given *finished* observations only.
        let mut pol = MmGpEi::new(&p);
        let r = simulate(
            &p,
            &t,
            &mut pol,
            &SimConfig { n_devices: m_devices, warm_start_per_user: 1, horizon: None, ..Default::default() },
        );
        let mut gp = mmgpei::gp::Gp::new(p.prior_mean.clone(), p.prior_cov.clone());
        // Events sorted by dispatch time; observations land at finish.
        let mut dispatches: Vec<_> = r.observations.clone();
        dispatches.sort_by(|a, b| a.start.total_cmp(&b.start));
        let mut completions: Vec<_> = r.observations.clone();
        completions.sort_by(|a, b| a.finish.total_cmp(&b.finish));
        let mut ci = 0;
        let mut sigma_hat_sum = 0.0;
        for d in &dispatches {
            while ci < completions.len() && completions[ci].finish <= d.start {
                gp.observe(completions[ci].arm, completions[ci].z);
                ci += 1;
            }
            sigma_hat_sum += gp.posterior_std(d.arm);
        }
        let miu = miu_total(&p.prior_cov, n_arms, |k, s| {
            if k.rows() <= 12 { miu_exact(k, s) } else { 0.0 }
        });
        assert!(
            sigma_hat_sum <= m_devices as f64 + miu + 1e-6,
            "Σσ̂ = {sigma_hat_sum} vs M + MIU = {}",
            m_devices as f64 + miu
        );
    });
}
