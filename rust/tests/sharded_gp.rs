//! Sharded block-Kronecker GP (`gp::ShardedGp`) vs the dense oracle.
//!
//! The contract under test, end to end:
//!  * **ρ = 0** (independent tenants): the sharded store is *bitwise* the
//!    dense factor — posteriors, dirty sets, EI, and backend-level
//!    selections, over a whole serving run;
//!  * **ρ > 0** (exchangeable cross-tenant coupling): the Woodbury
//!    cross-term matches the dense factorization of the materialized
//!    B(ρ) ⊗ C prior to tight relative tolerance, including through
//!    churn disable/enable replays and double-observe no-ops;
//!  * **determinism**: batch observes replay the sequential schedule bit
//!    for bit at any pool width, posterior snapshots are pool-width
//!    invariant, and a `[gp] structure = "sharded"` experiment serializes
//!    byte-identical reports at `threads = 1` and `threads = 4`.

use mmgpei::config::{ExperimentConfig, GpStructure};
use mmgpei::gp::{Gp, GpError, KroneckerPrior, ShardedGp};
use mmgpei::kernels::{Kernel, Matern52};
use mmgpei::pool::WorkerPool;
use mmgpei::report::RunReport;
use mmgpei::sched::{DeviceView, EiBackend, NativeBackend, ScoreMode};
use mmgpei::workload::{synthetic_gp, ChurnConfig, SyntheticConfig};

/// Shared Matérn-5/2 model gram over the workloads' `m · 0.25` grid.
fn model_gram(n_models: usize, variance: f64, lengthscale: f64) -> mmgpei::linalg::Mat {
    let pts: Vec<Vec<f64>> = (0..n_models).map(|m| vec![m as f64 * 0.25]).collect();
    Matern52 { variance, lengthscale }.gram(&pts)
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// Deterministic pseudo-observation for arm-index `k`.
fn z_for(k: usize) -> f64 {
    ((k * 37 + 11) % 97) as f64 / 97.0 - 0.5
}

#[test]
fn rho_zero_posteriors_dirty_sets_and_ei_are_bitwise_dense() {
    let cfg = SyntheticConfig { n_users: 8, n_models: 5, ..Default::default() };
    let (problem, truth) = synthetic_gp(&cfg, 0xD15E);
    let prior = KroneckerPrior::new(
        cfg.n_users,
        model_gram(cfg.n_models, cfg.variance, cfg.lengthscale),
        0.0,
        problem.prior_mean.clone(),
    )
    .unwrap();
    // The Kronecker form at ρ = 0 *is* the synthetic workload's
    // block-diagonal prior, bit for bit.
    let (kmean, kcov) = prior.dense_prior();
    assert_eq!(kmean, problem.prior_mean);
    assert_eq!(kcov, problem.prior_cov);

    let mut dense = Gp::new(problem.prior_mean.clone(), problem.prior_cov.clone());
    let mut sharded = ShardedGp::new(prior);
    let n = problem.n_arms();
    for k in 0..n / 2 {
        let x = (k * 7 + 3) % n;
        if dense.is_observed(x) {
            continue;
        }
        let d_dirty: Vec<usize> = dense.observe(x, truth.z[x]).to_vec();
        let s_dirty: Vec<usize> = sharded.observe(x, truth.z[x]).to_vec();
        assert_eq!(d_dirty, s_dirty, "dirty set diverged at step {k} (arm {x})");
        for a in 0..n {
            assert_eq!(
                dense.posterior_mean(a).to_bits(),
                sharded.posterior_mean(a).to_bits(),
                "mean bits diverged at arm {a} after observing {x}"
            );
            assert_eq!(
                dense.posterior_std(a).to_bits(),
                sharded.posterior_std(a).to_bits(),
                "std bits diverged at arm {a} after observing {x}"
            );
            let best = 0.2;
            assert_eq!(
                mmgpei::gp::expected_improvement(dense.posterior_mean(a), dense.posterior_std(a), best)
                    .to_bits(),
                sharded.ei(a, best).to_bits(),
                "EI bits diverged at arm {a}"
            );
        }
    }
}

#[test]
fn rho_positive_matches_dense_oracle_to_rel_tol() {
    let (n_users, n_models, rho) = (7usize, 4usize, 0.3f64);
    let prior =
        KroneckerPrior::constant_mean(n_users, model_gram(n_models, 1.0, 0.8), rho, 0.15).unwrap();
    let (mean, cov) = prior.dense_prior();
    let mut dense = Gp::new(mean, cov);
    let mut sharded = ShardedGp::new(prior);
    let n = sharded.n_arms();
    for k in 0..n / 2 {
        let x = (k * 5 + 2) % n;
        if dense.is_observed(x) {
            continue;
        }
        dense.observe(x, z_for(k));
        sharded.observe(x, z_for(k));
        for a in 0..n {
            assert!(
                rel_close(dense.posterior_mean(a), sharded.posterior_mean(a), 1e-9),
                "mean diverged at arm {a}: dense {} vs sharded {}",
                dense.posterior_mean(a),
                sharded.posterior_mean(a)
            );
            assert!(
                rel_close(dense.posterior_std(a), sharded.posterior_std(a), 1e-8),
                "std diverged at arm {a}: dense {} vs sharded {}",
                dense.posterior_std(a),
                sharded.posterior_std(a)
            );
        }
    }
    // EI rides on (mean, std), so it inherits the tolerance.
    for a in 0..n {
        let d_ei = mmgpei::gp::expected_improvement(dense.posterior_mean(a), dense.posterior_std(a), 0.1);
        assert!(rel_close(d_ei, sharded.ei(a, 0.1), 1e-7), "EI diverged at arm {a}");
    }
}

#[test]
fn churn_replay_with_disable_enable_and_double_observe_tracks_dense() {
    let (n_users, n_models, rho) = (6usize, 4usize, 0.3f64);
    let prior =
        KroneckerPrior::constant_mean(n_users, model_gram(n_models, 1.0, 0.8), rho, 0.0).unwrap();
    let (mean, cov) = prior.dense_prior();
    let mut dense = Gp::new(mean, cov);
    let mut sharded = ShardedGp::new(prior);
    let m = n_models;
    let n = sharded.n_arms();

    // Warm both stores, then tenant 2 departs.
    for (k, x) in [0usize, 5, 9, 14].into_iter().enumerate() {
        dense.observe(x, z_for(k));
        sharded.observe(x, z_for(k));
    }
    for x in 2 * m..3 * m {
        dense.disable_arm(x);
        sharded.disable_arm(x);
    }
    assert_eq!(sharded.n_enabled(), n - m);

    // Observations keep arriving while tenant 2 is away; its frozen
    // posterior must hold the pre-departure values on both stores.
    let frozen: Vec<(u64, u64)> =
        (2 * m..3 * m).map(|x| (sharded.posterior_mean(x).to_bits(), sharded.posterior_std(x).to_bits())).collect();
    for (k, x) in [1usize, 7, 13, 19].into_iter().enumerate() {
        dense.observe(x, z_for(k + 40));
        sharded.observe(x, z_for(k + 40));
    }
    for (i, x) in (2 * m..3 * m).enumerate() {
        assert_eq!(sharded.posterior_mean(x).to_bits(), frozen[i].0, "frozen mean drifted at arm {x}");
        assert_eq!(sharded.posterior_std(x).to_bits(), frozen[i].1, "frozen std drifted at arm {x}");
        assert!(
            rel_close(dense.posterior_mean(x), f64::from_bits(frozen[i].0), 1e-8),
            "dense frozen value disagrees at arm {x}"
        );
    }

    // Double observe: logged and skipped on both stores, posterior
    // untouched to the bit.
    let before: Vec<u64> = (0..n).map(|a| sharded.posterior_mean(a).to_bits()).collect();
    assert_eq!(sharded.try_observe(5, 99.0), Err(GpError::AlreadyObserved(5)));
    assert_eq!(dense.try_observe(5, 99.0), Err(GpError::AlreadyObserved(5)));
    assert!(sharded.observe(5, 99.0).is_empty(), "double observe must report no dirty arms");
    for a in 0..n {
        assert_eq!(sharded.posterior_mean(a).to_bits(), before[a], "double observe moved arm {a}");
    }

    // Tenant 2 rejoins: both stores catch up on everything it missed.
    for x in 2 * m..3 * m {
        dense.enable_arm(x);
        sharded.enable_arm(x);
    }
    assert_eq!(sharded.n_enabled(), n);
    for a in 0..n {
        assert!(
            rel_close(dense.posterior_mean(a), sharded.posterior_mean(a), 1e-8),
            "post-rejoin mean diverged at arm {a}"
        );
        assert!(
            rel_close(dense.posterior_std(a), sharded.posterior_std(a), 1e-7),
            "post-rejoin std diverged at arm {a}"
        );
    }
}

#[test]
fn backend_selections_and_scores_are_bitwise_dense_at_rho_zero() {
    let cfg = SyntheticConfig { n_users: 10, n_models: 4, ..Default::default() };
    let (problem, truth) = synthetic_gp(&cfg, 0xBACC);
    let prior = KroneckerPrior::new(
        cfg.n_users,
        model_gram(cfg.n_models, cfg.variance, cfg.lengthscale),
        0.0,
        problem.prior_mean.clone(),
    )
    .unwrap();
    let mut dense = NativeBackend::new(&problem);
    let mut sharded = NativeBackend::sharded(&problem, prior);
    assert_eq!(dense.label(), "native");
    assert_eq!(sharded.label(), "sharded");
    let n = problem.n_arms();
    let mut selected = vec![false; n];
    let mut best = vec![0.0f64; problem.n_users];
    let dev = DeviceView::unit(0);
    for k in 0..n / 2 {
        let d_pick = dense.select_arm(&best, &selected, ScoreMode::CostRate, dev);
        let s_pick = sharded.select_arm(&best, &selected, ScoreMode::CostRate, dev);
        assert_eq!(d_pick, s_pick, "selection diverged at decision {k}");
        let d_scores: Vec<u64> =
            dense.eirate(&best, &selected, ScoreMode::CostRate, dev).iter().map(|s| s.to_bits()).collect();
        let s_scores: Vec<u64> =
            sharded.eirate(&best, &selected, ScoreMode::CostRate, dev).iter().map(|s| s.to_bits()).collect();
        assert_eq!(d_scores, s_scores, "score bits diverged at decision {k}");
        let x = (k * 7 + 3) % n;
        if selected[x] {
            continue;
        }
        dense.observe(x, truth.z[x]);
        sharded.observe(x, truth.z[x]);
        selected[x] = true;
        for &u in &problem.arm_users[x] {
            best[u] = best[u].max(truth.z[x]);
        }
    }
}

#[test]
fn observe_batch_replays_sequential_bitwise_and_is_all_or_nothing() {
    let (n_users, n_models, rho) = (12usize, 4usize, 0.35f64);
    let prior =
        KroneckerPrior::constant_mean(n_users, model_gram(n_models, 1.0, 0.8), rho, 0.05).unwrap();
    let mut seq = ShardedGp::new(prior);
    let mut batch = seq.clone();
    let obs: Vec<(usize, f64)> = (0..16).map(|k| ((k * 5 + 1) % (n_users * n_models), z_for(k))).collect();
    // The stride-5 walk over 48 arms yields 16 distinct indices.
    for &(x, z) in &obs {
        seq.observe(x, z);
    }
    let pool = WorkerPool::new(4);
    batch.observe_batch(&pool, &obs).unwrap();
    let (sm, ss) = seq.posterior_snapshot(&pool);
    let (bm, bs) = batch.posterior_snapshot(&pool);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&sm), bits(&bm), "batch means must replay the sequential schedule exactly");
    assert_eq!(bits(&ss), bits(&bs), "batch stds must replay the sequential schedule exactly");

    // All-or-nothing: a duplicate poisons the whole batch, and the store
    // is untouched.
    let before = bits(&batch.posterior_snapshot(&pool).0);
    let dup = vec![(2usize, 0.4), (2usize, 0.5)];
    assert!(batch.observe_batch(&pool, &dup).is_err());
    let already = vec![(obs[0].0, 1.0)];
    assert_eq!(batch.observe_batch(&pool, &already), Err(GpError::AlreadyObserved(obs[0].0)));
    assert_eq!(bits(&batch.posterior_snapshot(&pool).0), before, "failed batch must not mutate");
}

#[test]
fn posterior_snapshot_is_pool_width_invariant() {
    let (n_users, n_models, rho) = (40usize, 3usize, 0.2f64);
    let prior =
        KroneckerPrior::constant_mean(n_users, model_gram(n_models, 1.0, 0.8), rho, 0.0).unwrap();
    let mut gp = ShardedGp::new(prior);
    for k in 0..30 {
        gp.observe((k * 11 + 4) % (n_users * n_models), z_for(k));
    }
    let (m1, s1) = gp.posterior_snapshot(&WorkerPool::new(1));
    let (m4, s4) = gp.posterior_snapshot(&WorkerPool::new(4));
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&m1), bits(&m4));
    assert_eq!(bits(&s1), bits(&s4));
}

#[test]
fn sharded_experiment_reports_are_byte_identical_across_thread_counts() {
    // The CI determinism gate in miniature: the same `[gp] structure =
    // "sharded"` sweep at width 1 and width 4 must serialize identically.
    let run = |threads: usize| -> String {
        let cfg = ExperimentConfig {
            name: "sharded-invariance".into(),
            dataset: "synthetic".into(),
            policies: vec!["mdmt".into(), "round-robin".into()],
            devices: vec![1, 2],
            seeds: 3,
            threads,
            gp_structure: GpStructure::Sharded,
            synthetic: SyntheticConfig { n_users: 6, n_models: 5, ..Default::default() },
            ..Default::default()
        };
        let res = mmgpei::cli::run_experiment(&cfg).expect("sharded sweep");
        let mut report = RunReport::new("sharded_invariance", 0, true);
        report.provenance.commit = "pinned".into(); // not thread-related
        res.push_kpis(&mut report, "syn/", &[0.05]);
        report.to_json_string()
    };
    let serial = run(1);
    let pooled = run(4);
    assert_eq!(serial, pooled, "sharded sweep must serialize byte-identically at any width");
    assert!(serial.contains("cumulative_regret"), "report must actually carry KPIs");

    // Same contract under churn (ρ > 0 exercises the Woodbury path).
    let run_churn = |threads: usize| -> String {
        let cfg = ExperimentConfig {
            name: "sharded-churn-invariance".into(),
            dataset: "synthetic".into(),
            policies: vec!["mdmt".into()],
            devices: vec![2],
            seeds: 2,
            threads,
            gp_structure: GpStructure::Sharded,
            churn: true,
            churn_cfg: ChurnConfig { n_users: 6, n_models: 4, initial_users: 2, ..Default::default() },
            ..Default::default()
        };
        let res = mmgpei::cli::run_churn_experiment(&cfg).expect("sharded churn sweep");
        let mut report = RunReport::new("sharded_churn_invariance", 0, true);
        report.provenance.commit = "pinned".into();
        res.push_kpis(&mut report, "churn/");
        report.to_json_string()
    };
    let serial = run_churn(1);
    assert_eq!(serial, run_churn(4), "sharded churn sweep must serialize byte-identically");
    assert!(serial.contains("mean_exit_regret"));
}
