//! Integration tests asserting the *shape* of the paper's headline
//! results (DESIGN.md §4): who wins, directionally by how much, and where
//! the effects disappear. Absolute numbers differ from the paper (our
//! substrate is a simulator over substituted tables); shapes must hold.

use mmgpei::metrics::mean_std;
use mmgpei::prng::Rng;
use mmgpei::sched::{GpEiRandom, GpEiRoundRobin, MmGpEi, Policy};
use mmgpei::sim::{simulate, SimConfig, SimResult};
use mmgpei::workload::{azure, deeplearning, synthetic_gp, Dataset, SyntheticConfig};

/// Run `make_policy` over `n_seeds` protocol resamplings; returns the
/// per-seed cumulative regrets.
fn run_seeds(
    data: &Dataset,
    n_devices: usize,
    n_seeds: u64,
    make_policy: impl Fn(&mmgpei::problem::Problem, u64) -> Box<dyn Policy>,
) -> Vec<SimResult> {
    (0..n_seeds)
        .map(|seed| {
            let mut rng = Rng::new(1000 + seed);
            let split = data.protocol_split(&mut rng, 8);
            let (problem, truth) = data.make_problem(&split);
            let mut policy = make_policy(&problem, seed);
            simulate(
                &problem,
                &truth,
                policy.as_mut(),
                &SimConfig { n_devices, warm_start_per_user: 2, horizon: None, ..Default::default() },
            )
        })
        .collect()
}

fn mean_cumulative(results: &[SimResult]) -> f64 {
    mean_std(&results.iter().map(|r| r.cumulative_regret).collect::<Vec<_>>()).0
}

/// Figure 2 (Azure panel): with a single device, GP-EI-MDMT beats both
/// round-robin and random on cumulative regret.
#[test]
fn fig2_shape_azure_mdmt_wins_single_device() {
    let data = azure();
    let n_seeds = 8;
    let mm = run_seeds(&data, 1, n_seeds, |p, _| Box::new(MmGpEi::new(p)));
    let rr = run_seeds(&data, 1, n_seeds, |p, _| Box::new(GpEiRoundRobin::new(p)));
    let rand = run_seeds(&data, 1, n_seeds, |p, s| Box::new(GpEiRandom::new(p, 77 + s)));
    let (m_mm, m_rr, m_rand) = (mean_cumulative(&mm), mean_cumulative(&rr), mean_cumulative(&rand));
    assert!(
        m_mm < m_rr,
        "Azure/1dev: MDMT ({m_mm:.2}) must beat round-robin ({m_rr:.2})"
    );
    assert!(
        m_mm < m_rand,
        "Azure/1dev: MDMT ({m_mm:.2}) must beat random ({m_rand:.2})"
    );
}

/// Figure 2 (DeepLearning panel): the gap is small — the paper reports no
/// significant speedup because warm-start already lands within σ≈0.04 of
/// optimal. We assert MDMT is not significantly *worse* (within 25%).
#[test]
fn fig2_shape_deeplearning_near_parity() {
    let data = deeplearning();
    let n_seeds = 8;
    let mm = run_seeds(&data, 1, n_seeds, |p, _| Box::new(MmGpEi::new(p)));
    let rr = run_seeds(&data, 1, n_seeds, |p, _| Box::new(GpEiRoundRobin::new(p)));
    let (m_mm, m_rr) = (mean_cumulative(&mm), mean_cumulative(&rr));
    assert!(
        m_mm < 1.25 * m_rr,
        "DeepLearning/1dev: MDMT ({m_mm:.2}) should be ≈ round-robin ({m_rr:.2})"
    );
}

/// Figure 3 shape: more devices → faster instantaneous-regret decay for
/// MDMT (strictly smaller cumulative regret as M doubles).
#[test]
fn fig3_shape_more_devices_help() {
    let data = azure();
    let n_seeds = 6;
    let m1 = mean_cumulative(&run_seeds(&data, 1, n_seeds, |p, _| Box::new(MmGpEi::new(p))));
    let m2 = mean_cumulative(&run_seeds(&data, 2, n_seeds, |p, _| Box::new(MmGpEi::new(p))));
    let m4 = mean_cumulative(&run_seeds(&data, 4, n_seeds, |p, _| Box::new(MmGpEi::new(p))));
    assert!(m2 < m1, "2 devices ({m2:.2}) must beat 1 ({m1:.2})");
    assert!(m4 < m2, "4 devices ({m4:.2}) must beat 2 ({m2:.2})");
}

/// Figure 4 shape: at M=8 on Azure (9 served users) MDMT and round-robin
/// nearly coincide — with as many devices as users there is nothing to
/// prioritize. The paper calls this out explicitly.
#[test]
fn fig4_shape_m8_parity_on_azure() {
    let data = azure();
    let n_seeds = 6;
    let mm = mean_cumulative(&run_seeds(&data, 8, n_seeds, |p, _| Box::new(MmGpEi::new(p))));
    let rr =
        mean_cumulative(&run_seeds(&data, 8, n_seeds, |p, _| Box::new(GpEiRoundRobin::new(p))));
    let ratio = mm / rr;
    assert!(
        (0.75..=1.25).contains(&ratio),
        "Azure/8dev: MDMT vs RR should be near parity, ratio {ratio:.3}"
    );
    // …while at M=4 MDMT still wins.
    let mm4 = mean_cumulative(&run_seeds(&data, 4, n_seeds, |p, _| Box::new(MmGpEi::new(p))));
    let rr4 =
        mean_cumulative(&run_seeds(&data, 4, n_seeds, |p, _| Box::new(GpEiRoundRobin::new(p))));
    assert!(mm4 < rr4, "Azure/4dev: MDMT ({mm4:.2}) must beat RR ({rr4:.2})");
}

/// Figure 5 shape: near-linear speedup of time-to-cutoff while M ≪ N on
/// the synthetic workload (small version for test speed; the bench runs
/// the paper's 50×50).
#[test]
fn fig5_shape_near_linear_speedup() {
    let cfg = SyntheticConfig { n_users: 16, n_models: 12, ..Default::default() };
    let cutoff = 0.01;
    let time_at = |m: usize| -> f64 {
        let times: Vec<f64> = (0..3)
            .map(|seed| {
                let (p, t) = synthetic_gp(&cfg, 500 + seed);
                let mut pol = MmGpEi::new(&p);
                let r = simulate(
                    &p,
                    &t,
                    &mut pol,
                    &SimConfig { n_devices: m, warm_start_per_user: 2, horizon: None, ..Default::default() },
                );
                r.time_to(cutoff).expect("cutoff must be reached (all arms eventually run)")
            })
            .collect();
        mean_std(&times).0
    };
    let t1 = time_at(1);
    let t2 = time_at(2);
    let t4 = time_at(4);
    let s2 = t1 / t2;
    let s4 = t1 / t4;
    assert!(s2 > 1.4, "2-device speedup should be near-linear, got {s2:.2}");
    assert!(s4 > 2.2, "4-device speedup should be near-linear, got {s4:.2}");
    assert!(s4 > s2, "speedup must grow with devices");
}
