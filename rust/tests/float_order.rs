//! Regression tests for the `partial_cmp → total_cmp` sweep (pallas-lint
//! rule R1). Two claims are pinned:
//!
//! 1. On the finite inputs every shipped workload produces, `total_cmp`
//!    sorts in exactly the order the old `partial_cmp().unwrap()` code
//!    did — the sweep is behavior-preserving where the old code worked.
//! 2. Where the old code *panicked* (NaN reaching a comparator), the
//!    public entry points now complete and return something sane.
//!
//! This file is *inside* the lint's sweep scope (CI lints `rust/tests`
//! too), so its deliberate `partial_cmp` reference comparators carry
//! justified `allow(R1)` pragmas — they exist to check parity against
//! the old semantics, not to order floats for real.

use mmgpei::gp::nelder_mead;
use mmgpei::linalg::Mat;
use mmgpei::miu::miu_diag_bound;
use mmgpei::problem::{Problem, Truth};
use mmgpei::testutil::check;

/// A problem with explicit costs and a shared arm; `validate()` is NOT
/// called so NaN costs can be injected to exercise the no-panic paths.
fn raw_problem(cost: Vec<f64>) -> Problem {
    let n_arms = cost.len();
    let user_arms = vec![(0..n_arms).collect::<Vec<_>>()];
    let arm_users = Problem::compute_arm_users(n_arms, &user_arms);
    Problem {
        name: "float-order".into(),
        n_users: 1,
        cost,
        user_arms,
        arm_users,
        prior_mean: vec![0.0; n_arms],
        prior_cov: Mat::from_fn(n_arms, n_arms, |i, j| if i == j { 1.0 } else { 0.0 }),
    }
}

#[test]
fn total_cmp_sort_matches_partial_cmp_on_finite_inputs() {
    // Mixed-sign zeros are excluded: partial_cmp calls them Equal (stable
    // sort keeps input order) while total_cmp orders -0.0 < +0.0. No
    // shipped cost/score path produces -0.0, so parity on nonzero finite
    // values is the invariant that matters.
    check("total_cmp order parity", |rng| {
        let xs: Vec<f64> = (0..40)
            .map(|_| {
                let magnitude = rng.uniform_in(1e-6, 1e6);
                if rng.below(2) == 0 { magnitude } else { -magnitude }
            })
            .collect();
        let mut by_total = xs.clone();
        by_total.sort_by(|a, b| a.total_cmp(b));
        let mut by_partial = xs;
        // pallas-lint: allow(R1) — this IS the reference comparator the parity test compares total_cmp against; inputs are finite by construction.
        by_partial.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(by_total, by_partial);
    });
}

#[test]
fn total_cmp_max_matches_partial_cmp_on_finite_inputs() {
    check("total_cmp max parity", |rng| {
        let xs: Vec<f64> = (0..17).map(|_| rng.uniform_in(-50.0, 50.0)).collect();
        let max_total = xs.iter().copied().max_by(|a, b| a.total_cmp(b));
        // pallas-lint: allow(R1) — reference comparator for the max-parity claim; inputs are finite by construction.
        let max_partial = xs.iter().copied().max_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(max_total, max_partial);
    });
}

#[test]
fn warm_start_survives_nan_cost() {
    // Old code: sort_by(partial_cmp().unwrap()) aborted the service on a
    // NaN cost. Now the NaN arm totally orders after every finite cost,
    // so it is simply never warm-started.
    let p = raw_problem(vec![3.0, f64::NAN, 1.0, 2.0]);
    let picked = p.warm_start_arms(2);
    assert_eq!(picked, vec![2, 3], "cheapest two finite arms, NaN last");
}

#[test]
fn best_arm_survives_nan_performance() {
    let p = raw_problem(vec![1.0, 1.0, 1.0]);
    let t = Truth { z: vec![0.3, f64::NAN, 0.9] };
    // No panic; the returned arm is a valid index. (Positive NaN sorts
    // greatest under the IEEE total order, so it wins the argmax — the
    // caller sees a deterministic answer instead of an abort.)
    let best = t.best_arm(&p, 0);
    assert!(best < 3);
}

#[test]
fn miu_diag_bound_survives_nan_diagonal() {
    let k = Mat::from_fn(3, 3, |i, j| {
        if i == 1 && j == 1 {
            f64::NAN
        } else if i == j {
            1.0
        } else {
            0.0
        }
    });
    // `max(0.0)` clamps the NaN variance to 0 before the sort; the bound
    // stays finite and the sort cannot panic.
    let bound = miu_diag_bound(&k, 3);
    assert!(bound.is_finite());
    assert!((bound - 2.0).abs() < 1e-12);
}

#[test]
fn nelder_mead_survives_nan_objective() {
    // Old code panicked ordering the simplex the first time the objective
    // returned NaN (e.g. a Cholesky failure inside the LML). Now the
    // optimizer terminates and reports the NaN rather than aborting.
    let (x, fx) = nelder_mead(|_| f64::NAN, &[0.5], 0.1, 1e-9, 25);
    assert_eq!(x.len(), 1);
    assert!(fx.is_nan());
}

#[test]
fn nelder_mead_survives_partially_nan_objective() {
    // NaN on half the domain: the simplex must still converge toward the
    // finite half. x ≥ 0 → (x-1)²; x < 0 → NaN (positive NaN sorts worst
    // under total order, so NaN vertices are discarded first).
    let f = |v: &[f64]| if v[0] >= 0.0 { (v[0] - 1.0).powi(2) } else { f64::NAN };
    let (x, fx) = nelder_mead(f, &[0.2], 0.3, 1e-10, 200);
    assert!(fx.is_finite());
    assert!((x[0] - 1.0).abs() < 1e-3, "argmin {x:?}, min {fx}");
}
