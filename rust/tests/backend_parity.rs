//! Native-vs-XLA backend parity: the AOT artifact (JAX + Pallas, lowered
//! to HLO and executed through PJRT) must agree with the native
//! incremental-Cholesky GP to tight numeric tolerance, and the full
//! MM-GP-EI policy must make identical decisions with either backend.
//!
//! Requires the `xla` feature (the whole file is compiled out of the
//! default build — the stub backend can never load an artifact) plus
//! `make artifacts`; with the feature on, tests are skipped (with a loud
//! message) when the artifact directory is missing so `cargo test` stays
//! runnable before the first artifact build.
#![cfg(feature = "xla")]

use std::path::PathBuf;

use mmgpei::prng::Rng;
use mmgpei::runtime::{default_artifact_dir, XlaBackend};
use mmgpei::sched::{DeviceView, EiBackend, MmGpEi, NativeBackend, Policy, SchedContext, ScoreMode};
use mmgpei::sim::{simulate, SimConfig};
use mmgpei::workload::azure;

fn artifact_dir() -> Option<PathBuf> {
    let dir = default_artifact_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

/// Build the paper's Azure protocol instance (9 users × 8 models).
fn azure_instance(seed: u64) -> (mmgpei::problem::Problem, mmgpei::problem::Truth) {
    let data = azure();
    let mut rng = Rng::new(seed);
    let split = data.protocol_split(&mut rng, 8);
    data.make_problem(&split)
}

#[test]
fn posterior_and_eirate_agree() {
    let Some(dir) = artifact_dir() else { return };
    let (problem, truth) = azure_instance(2024);
    let mut native = NativeBackend::new(&problem);
    let mut xla = XlaBackend::new(&problem, &dir).expect("load artifact");

    // Feed identical observation streams.
    let mut rng = Rng::new(7);
    let mut selected = vec![false; problem.n_arms()];
    let mut best = vec![0.0f64; problem.n_users];
    for step in 0..10 {
        let arm = loop {
            let a = rng.below(problem.n_arms());
            if !selected[a] {
                break a;
            }
        };
        selected[arm] = true;
        let z = truth.z[arm];
        native.observe(arm, z);
        xla.observe(arm, z);
        for &u in &problem.arm_users[arm] {
            best[u] = best[u].max(z);
        }

        let (mu_n, sd_n) = native.posterior();
        let (mu_x, sd_x) = xla.posterior();
        for a in 0..problem.n_arms() {
            assert!(
                (mu_n[a] - mu_x[a]).abs() < 1e-6,
                "step {step} arm {a}: mu native {} vs xla {}",
                mu_n[a],
                mu_x[a]
            );
            assert!(
                (sd_n[a] - sd_x[a]).abs() < 1e-6,
                "step {step} arm {a}: sigma native {} vs xla {}",
                sd_n[a],
                sd_x[a]
            );
        }

        let e_n = native.eirate(&best, &selected, ScoreMode::CostRate, DeviceView::unit(0));
        let e_x = xla.eirate(&best, &selected, ScoreMode::CostRate, DeviceView::unit(0));
        for a in 0..problem.n_arms() {
            if selected[a] {
                assert!(e_n[a] == f64::NEG_INFINITY || e_n[a] <= -1e29);
                assert!(e_x[a] <= -1e29);
            } else {
                assert!(
                    (e_n[a] - e_x[a]).abs() < 1e-6 * (1.0 + e_n[a].abs()),
                    "step {step} arm {a}: eirate native {} vs xla {}",
                    e_n[a],
                    e_x[a]
                );
            }
        }
    }
}

#[test]
fn full_policy_runs_identically() {
    let Some(dir) = artifact_dir() else { return };
    let (problem, truth) = azure_instance(99);
    let cfg = SimConfig { n_devices: 2, warm_start_per_user: 2, horizon: None, ..Default::default() };

    let r_native = {
        let mut p = MmGpEi::new(&problem);
        simulate(&problem, &truth, &mut p, &cfg)
    };
    let r_xla = {
        let backend = XlaBackend::new(&problem, &dir).expect("load artifact");
        let mut p = MmGpEi::with_backend(&problem, Box::new(backend));
        simulate(&problem, &truth, &mut p, &cfg)
    };

    // Same decisions → identical observation sequences and regret.
    let arms_native: Vec<_> = r_native.observations.iter().map(|o| o.arm).collect();
    let arms_xla: Vec<_> = r_xla.observations.iter().map(|o| o.arm).collect();
    assert_eq!(arms_native, arms_xla, "backends must schedule identically");
    assert!(
        (r_native.cumulative_regret - r_xla.cumulative_regret).abs() < 1e-9,
        "regret parity: {} vs {}",
        r_native.cumulative_regret,
        r_xla.cumulative_regret
    );
}

#[test]
fn ei_only_ablation_parity() {
    let Some(dir) = artifact_dir() else { return };
    let (problem, truth) = azure_instance(7);
    let mut native = NativeBackend::new(&problem);
    let mut xla = XlaBackend::new(&problem, &dir).expect("load artifact");
    let selected = {
        let mut s = vec![false; problem.n_arms()];
        for a in 0..6 {
            s[a] = true;
            native.observe(a, truth.z[a]);
            xla.observe(a, truth.z[a]);
        }
        s
    };
    let mut best = vec![0.0f64; problem.n_users];
    for a in 0..6 {
        for &u in &problem.arm_users[a] {
            best[u] = best[u].max(truth.z[a]);
        }
    }
    let e_n = native.eirate(&best, &selected, ScoreMode::EiOnly, DeviceView::unit(0));
    let e_x = xla.eirate(&best, &selected, ScoreMode::EiOnly, DeviceView::unit(0));
    for a in 6..problem.n_arms() {
        assert!(
            (e_n[a] - e_x[a]).abs() < 1e-6 * (1.0 + e_n[a].abs()),
            "arm {a}: EI-only native {} vs xla {}",
            e_n[a],
            e_x[a]
        );
    }
}

#[test]
fn xla_scores_match_policy_argmax_semantics() {
    // The MmGpEi policy must pick the same arm whether scores come from
    // native or xla, including at the very first decision (no obs).
    let Some(dir) = artifact_dir() else { return };
    let (problem, _) = azure_instance(1234);
    let selected = vec![false; problem.n_arms()];
    let observed = vec![false; problem.n_arms()];
    let ctx = SchedContext {
        problem: &problem,
        selected: &selected,
        observed: &observed,
        now: 0.0,
        device: DeviceView::unit(0),
    };
    let pick_native = MmGpEi::new(&problem).select(&ctx).unwrap();
    let backend = XlaBackend::new(&problem, &dir).expect("load artifact");
    let pick_xla = MmGpEi::with_backend(&problem, Box::new(backend)).select(&ctx).unwrap();
    assert_eq!(pick_native, pick_xla);
}
