//! Cross-loop and cross-scenario parity gates for the unified engine.
//!
//! The engine refactor's acceptance criteria in executable form:
//!
//! 1. **Unit-fleet oracle**: with all speeds = 1 and no fleet churn, the
//!    engine's fleet path replays the plain simulator **byte-for-byte**
//!    (schedules, regret floats, curves) for every policy family — i.e.
//!    the refactor cannot have moved a single bit of the paper's
//!    figures. (CI additionally `cmp`s whole smoke reports; this is the
//!    in-repo, always-on version.)
//! 2. **Cross-loop parity**: the *wall-clock adapter* driven by the
//!    deterministic mock clock and the *virtual-time adapter* replay the
//!    same churn trace identically — schedules, per-tenant regret,
//!    curves, join latencies, and the serialized report bytes. Before
//!    the engine, `sim` and `coordinator` were only ever tested
//!    separately.
//! 3. **Preemption semantics**: speeds obey `c(x)/s_d`, a preempted arm
//!    reveals nothing and is re-served, and the in-place device hooks
//!    match the `ForceRebuild` oracle bit-for-bit.
//! 4. **Device-aware degeneration**: on a uniform unit-speed fleet,
//!    device-aware scoring (`EI/(c(x, class_d)/s_d)`) collapses to the
//!    paper's `EI/c(x)` **bitwise** — with or without an explicit
//!    [`UniformCost`] table — and the device-aware in-place hooks match
//!    the rebuild oracle under fleet churn.
//! 5. **Fault-trace parity**: the wall-clock fleet adapter on the mock
//!    clock replays `sim::simulate_faults` bit for bit under a
//!    preemption-heavy fault trace (crashes, lost jobs, stragglers,
//!    deadline kills) — schedules, regret floats, fault counters, and
//!    the serialized report bytes.

use std::time::Duration;

use mmgpei::coordinator::{
    serve_churn_deterministic, serve_fleet_deterministic, ChurnServeReport, ServeConfig,
};
use mmgpei::engine::FaultStats;
use mmgpei::problem::{
    CostModel, DeviceFleet, FaultEvent, FaultKind, FaultPlan, FleetEvent, FleetEventKind,
    PerClassCost, Problem, RetryPolicy, UniformCost,
};
use mmgpei::report::{Direction, RunReport};
use mmgpei::sched::{ForceRebuild, GpEiRandom, GpEiRoundRobin, MmGpEi, Policy};
use mmgpei::sim::{
    simulate, simulate_churn, simulate_faults, simulate_fleet, simulate_fleet_with_cost_model,
    ChurnResult, SimConfig, SimResult,
};
use mmgpei::workload::{
    churn_workload, fault_plan, fleet_schedule, round_robin_classes, synthetic_gp, ChurnConfig,
    FaultsConfig, FleetConfig, SyntheticConfig,
};

fn synthetic_instance(seed: u64) -> (Problem, mmgpei::problem::Truth) {
    synthetic_gp(&SyntheticConfig { n_users: 6, n_models: 5, ..Default::default() }, seed)
}

fn sim_key(r: &SimResult) -> Vec<(usize, usize, u64, u64, u64)> {
    r.observations
        .iter()
        .map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits(), o.z.to_bits()))
        .collect()
}

// ---------------------------------------------------------------------
// 1. Unit-fleet oracle: the engine's fleet path == the plain simulator.
// ---------------------------------------------------------------------

/// Assert the unit-fleet engine path bit-matches the plain simulator
/// for one (policy factory, device count) pair.
fn assert_unit_fleet_parity(
    name: &str,
    factory: &dyn Fn(&Problem) -> Box<dyn Policy>,
    p: &Problem,
    t: &mmgpei::problem::Truth,
    devices: usize,
    seed: u64,
) {
    let cfg = SimConfig { n_devices: devices, ..Default::default() };
    let mut plain_policy = factory(p);
    let plain = simulate(p, t, plain_policy.as_mut(), &cfg);
    let fleet = DeviceFleet::uniform(devices);
    let elastic = simulate_fleet(p, t, &fleet, factory, &cfg);
    assert_eq!(elastic.n_preemptions, 0);
    assert_eq!(elastic.n_rebuilds, 0);
    assert_eq!(
        sim_key(&plain),
        sim_key(&elastic.sim),
        "{name} @M{devices} seed {seed}: schedule diverged"
    );
    assert_eq!(
        plain.cumulative_regret.to_bits(),
        elastic.sim.cumulative_regret.to_bits(),
        "{name} @M{devices} seed {seed}: regret diverged"
    );
    assert_eq!(plain.inst_regret, elastic.sim.inst_regret);
    assert_eq!(plain.makespan.to_bits(), elastic.sim.makespan.to_bits());
    assert_eq!(plain.n_decisions, elastic.sim.n_decisions);
}

#[test]
fn unit_fleet_replays_plain_simulate_for_every_policy_family() {
    for seed in [0u64, 3, 9] {
        let (p, t) = synthetic_instance(0x517 + seed);
        for devices in [1usize, 2, 4] {
            assert_unit_fleet_parity(
                "mdmt",
                &|p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) },
                &p,
                &t,
                devices,
                seed,
            );
            assert_unit_fleet_parity(
                "round-robin",
                &|p: &Problem| -> Box<dyn Policy> { Box::new(GpEiRoundRobin::new(p)) },
                &p,
                &t,
                devices,
                seed,
            );
            assert_unit_fleet_parity(
                "random",
                &move |p: &Problem| -> Box<dyn Policy> {
                    Box::new(GpEiRandom::new(p, seed ^ 0x5EED))
                },
                &p,
                &t,
                devices,
                seed,
            );
        }
    }
}

#[test]
fn unit_fleet_oracle_with_horizon_and_cutoff_knobs() {
    // The engine owns horizon extension/truncation and the Figure-5
    // cutoff; the unit-fleet path must agree with the plain simulator
    // under those knobs too.
    let (p, t) = synthetic_instance(0x517);
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    for cfg in [
        SimConfig { n_devices: 2, horizon: Some(4.0), ..Default::default() },
        SimConfig { n_devices: 2, horizon: Some(1e4), ..Default::default() },
        SimConfig { n_devices: 2, stop_at_cutoff: Some(0.05), ..Default::default() },
    ] {
        let mut pol = MmGpEi::new(&p);
        let plain = simulate(&p, &t, &mut pol, &cfg);
        let elastic = simulate_fleet(&p, &t, &DeviceFleet::uniform(2), &factory, &cfg);
        assert_eq!(sim_key(&plain), sim_key(&elastic.sim));
        assert_eq!(plain.cumulative_regret.to_bits(), elastic.sim.cumulative_regret.to_bits());
        assert_eq!(plain.horizon.to_bits(), elastic.sim.horizon.to_bits());
        assert_eq!(plain.inst_regret, elastic.sim.inst_regret);
    }
}

// ---------------------------------------------------------------------
// 2. Cross-loop parity: mock-clock wall adapter vs virtual adapter.
// ---------------------------------------------------------------------

fn churn_trace() -> (Problem, mmgpei::problem::Truth, mmgpei::problem::ChurnSchedule) {
    churn_workload(
        &ChurnConfig {
            n_users: 6,
            n_models: 4,
            initial_users: 2,
            arrival_gap: 2.0,
            sojourn: (6.0, 14.0),
            rejoin_prob: 0.5,
            rejoin_gap: 3.0,
            ..Default::default()
        },
        23,
    )
}

/// Fold a churn run into a smoke report: one KPI per deterministic
/// quantity, so two runs serialize identically iff they agree float for
/// float.
fn churn_report(
    name: &str,
    cumulative: f64,
    per_user: &[f64],
    join_latency_secs: &[Option<f64>],
    n_rebuilds: usize,
    n_decisions: usize,
) -> String {
    let mut r = RunReport::new(name, 0, true);
    r.push_kpi("cumulative_regret", cumulative, Direction::LowerIsBetter);
    for (u, &x) in per_user.iter().enumerate() {
        r.push_kpi(format!("per_user_regret/u{u}"), x, Direction::LowerIsBetter);
    }
    for (u, l) in join_latency_secs.iter().enumerate() {
        if let Some(l) = l {
            r.push_kpi(format!("join_latency/u{u}"), *l, Direction::LowerIsBetter);
        }
    }
    r.push_kpi("rebuilds", n_rebuilds as f64, Direction::LowerIsBetter);
    r.push_kpi("decisions", n_decisions as f64, Direction::LowerIsBetter);
    r.to_json_string()
}

#[test]
fn wall_adapter_on_mock_clock_matches_virtual_adapter_bitwise() {
    let (p, t, s) = churn_trace();
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let devices = 2usize;
    let virtual_run: ChurnResult = simulate_churn(
        &p,
        &t,
        &s,
        &factory,
        // No horizon: live sessions report what actually ran, so the
        // virtual side must use the same accounting.
        &SimConfig { n_devices: devices, warm_start_per_user: 2, horizon: None, stop_at_cutoff: None },
    );
    let wall_run: ChurnServeReport = serve_churn_deterministic(
        &p,
        &t,
        &s,
        &factory,
        &ServeConfig { n_devices: devices, time_scale: 1.0, warm_start_per_user: 2, verbose: false },
    );

    // Schedules: same arms on the same devices at the same instants.
    let v_key: Vec<(usize, usize, Duration, Duration)> = virtual_run
        .observations
        .iter()
        .map(|o| {
            (
                o.arm,
                o.device,
                Duration::from_secs_f64(o.start.max(0.0)),
                Duration::from_secs_f64(o.finish.max(0.0)),
            )
        })
        .collect();
    let w_key: Vec<(usize, usize, Duration, Duration)> =
        wall_run.jobs.iter().map(|j| (j.arm, j.device, j.start, j.finish)).collect();
    assert_eq!(v_key, w_key, "wall and virtual adapters must replay one schedule");

    // Regret accounting: identical floats.
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&virtual_run.per_user_regret), bits(&wall_run.per_user_regret));
    assert_eq!(
        virtual_run.cumulative_regret.to_bits(),
        wall_run.per_user_regret.iter().sum::<f64>().to_bits()
    );
    assert_eq!(virtual_run.inst_regret, wall_run.inst_regret, "regret curves must be identical");

    // Join latencies (Duration on the wall side — compare through the
    // same conversion).
    let v_lat: Vec<Option<Duration>> = virtual_run
        .join_latency
        .iter()
        .map(|l| l.map(|x| Duration::from_secs_f64(x.max(0.0))))
        .collect();
    assert_eq!(v_lat, wall_run.join_latency);

    assert_eq!(virtual_run.n_rebuilds, wall_run.n_rebuilds);
    assert_eq!(virtual_run.n_decisions, wall_run.decision_latencies.len());

    // Report bytes: folding both runs' deterministic quantities into the
    // report schema must serialize byte-identically. Join latencies are
    // compared through the same Duration conversion on both sides (the
    // wall report type stores them nanosecond-quantized).
    let w_lat_secs: Vec<Option<f64>> =
        wall_run.join_latency.iter().map(|l| l.map(|d| d.as_secs_f64())).collect();
    let v_lat_secs: Vec<Option<f64>> = virtual_run
        .join_latency
        .iter()
        .map(|l| l.map(|x| Duration::from_secs_f64(x.max(0.0)).as_secs_f64()))
        .collect();
    assert_eq!(v_lat_secs, w_lat_secs);
    let v_report = churn_report(
        "cross-loop",
        virtual_run.cumulative_regret,
        &virtual_run.per_user_regret,
        &v_lat_secs,
        virtual_run.n_rebuilds,
        virtual_run.n_decisions,
    );
    let w_report = churn_report(
        "cross-loop",
        wall_run.per_user_regret.iter().sum(),
        &wall_run.per_user_regret,
        &w_lat_secs,
        wall_run.n_rebuilds,
        wall_run.decision_latencies.len(),
    );
    assert_eq!(v_report, w_report, "cross-loop report bytes must be identical");
}

#[test]
fn wall_adapter_rebuild_fallback_matches_virtual_adapter() {
    // Same cross-loop parity through the *rebuild* path (baselines keep
    // the default hooks).
    let (p, t, s) = churn_trace();
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(GpEiRoundRobin::new(p)) };
    let v = simulate_churn(&p, &t, &s, &factory, &SimConfig { n_devices: 2, ..Default::default() });
    let w = serve_churn_deterministic(
        &p,
        &t,
        &s,
        &factory,
        &ServeConfig { n_devices: 2, time_scale: 1.0, warm_start_per_user: 2, verbose: false },
    );
    assert!(v.n_rebuilds > 0, "round-robin churns through the rebuild path");
    assert_eq!(v.n_rebuilds, w.n_rebuilds);
    let v_arms: Vec<(usize, usize)> = v.observations.iter().map(|o| (o.arm, o.device)).collect();
    let w_arms: Vec<(usize, usize)> = w.jobs.iter().map(|j| (j.arm, j.device)).collect();
    assert_eq!(v_arms, w_arms);
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&v.per_user_regret), bits(&w.per_user_regret));
}

// ---------------------------------------------------------------------
// 3. Elastic-fleet semantics.
// ---------------------------------------------------------------------

#[test]
fn speeds_obey_cost_over_speed_rule() {
    let (p, t) = synthetic_instance(0x99);
    let fleet = fleet_schedule(
        &FleetConfig {
            n_devices: 4,
            initial_online: 3,
            uptime: (10.0, 25.0),
            outage: (3.0, 8.0),
            horizon: 60.0,
            ..Default::default()
        },
        7,
    );
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let r = simulate_fleet(&p, &t, &fleet, &factory, &SimConfig::default());
    assert!(!r.sim.observations.is_empty());
    for o in &r.sim.observations {
        let expected = p.cost[o.arm] / fleet.speed(o.device);
        assert!(
            (o.finish - o.start - expected).abs() < 1e-9,
            "arm {} on device {} took {} (expected {expected})",
            o.arm,
            o.device,
            o.finish - o.start
        );
    }
}

#[test]
fn preempted_arms_reveal_nothing_and_are_reserved() {
    // Aggressive churn so preemptions actually happen, across seeds.
    let cfg = FleetConfig {
        n_devices: 3,
        initial_online: 3,
        uptime: (2.0, 6.0),
        outage: (1.0, 3.0),
        horizon: 80.0,
        ..Default::default()
    };
    let mut any_preempt = false;
    for seed in 0..6u64 {
        let (p, t) = synthetic_instance(0x200 + seed);
        let fleet = fleet_schedule(&cfg, seed);
        let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let r = simulate_fleet(&p, &t, &fleet, &factory, &SimConfig::default());
        any_preempt |= r.n_preemptions > 0;
        // Revealed-on-completion: every observation is a real completion
        // with the true z, and no arm completes twice.
        let mut seen = vec![false; p.n_arms()];
        for o in &r.sim.observations {
            assert!(!seen[o.arm], "arm {} observed twice", o.arm);
            seen[o.arm] = true;
            assert_eq!(o.z.to_bits(), t.z[o.arm].to_bits());
        }
        // Requeue latencies are finite and non-negative.
        for &l in &r.requeue_latency {
            assert!(l.is_finite() && l >= 0.0);
        }
        assert!(r.requeue_latency.len() <= r.n_preemptions);
        // Deterministic replay of the whole elastic run.
        let r2 = simulate_fleet(&p, &t, &fleet, &factory, &SimConfig::default());
        assert_eq!(sim_key(&r.sim), sim_key(&r2.sim));
        assert_eq!(r.n_preemptions, r2.n_preemptions);
    }
    assert!(any_preempt, "the aggressive schedule must preempt at least once across seeds");
}

#[test]
fn inplace_device_hooks_match_force_rebuild_oracle() {
    let cfg = FleetConfig {
        n_devices: 3,
        initial_online: 2,
        uptime: (4.0, 10.0),
        outage: (2.0, 5.0),
        horizon: 50.0,
        ..Default::default()
    };
    for seed in 0..4u64 {
        let (p, t) = synthetic_instance(0x300 + seed);
        let fleet = fleet_schedule(&cfg, 100 + seed);
        let inc = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
        let oracle = |p: &Problem| -> Box<dyn Policy> { Box::new(ForceRebuild(MmGpEi::new(p))) };
        let a = simulate_fleet(&p, &t, &fleet, &inc, &SimConfig::default());
        let b = simulate_fleet(&p, &t, &fleet, &oracle, &SimConfig::default());
        assert_eq!(a.n_rebuilds, 0, "in-place path never rebuilds");
        if !fleet.events().is_empty() && !b.sim.observations.is_empty() {
            // The oracle rebuilds on every device event that lands after
            // the first completion.
            assert!(
                b.n_rebuilds > 0 || fleet.events().iter().all(|e| e.time == 0.0),
                "oracle must exercise the rebuild path (seed {seed})"
            );
        }
        assert_eq!(sim_key(&a.sim), sim_key(&b.sim), "seed {seed}: schedules diverged");
        assert_eq!(a.sim.cumulative_regret.to_bits(), b.sim.cumulative_regret.to_bits());
        assert_eq!(a.sim.inst_regret, b.sim.inst_regret);
        assert_eq!(a.n_preemptions, b.n_preemptions);
    }
}

// ---------------------------------------------------------------------
// 4. Device-aware degeneration + device-aware hook parity.
// ---------------------------------------------------------------------

/// Fold a fleet run's deterministic quantities into a smoke report so
/// two runs serialize byte-identically iff they agree float for float.
/// KPI-only on purpose: the device-aware and device-blind policies carry
/// different display names, which must not enter the parity comparison.
fn fleet_report(name: &str, r: &SimResult) -> String {
    let mut rep = RunReport::new(name, 0, true);
    rep.push_kpi("cumulative_regret", r.cumulative_regret, Direction::LowerIsBetter);
    rep.push_kpi("final_regret", r.inst_regret.final_value(), Direction::LowerIsBetter);
    rep.push_kpi("makespan", r.makespan, Direction::LowerIsBetter);
    rep.push_kpi("decisions", r.n_decisions as f64, Direction::LowerIsBetter);
    rep.to_json_string()
}

#[test]
fn device_aware_on_unit_fleet_matches_device_blind_report_bytes() {
    // `EI/(c/1.0)` divides by the very same float as `EI/c`, so on a
    // uniform unit-speed single-class fleet the device-aware policy must
    // replay the device-blind one byte for byte — schedules, regret
    // floats, and serialized report bytes — both without a cost model
    // and with an explicit byte-compatible `UniformCost` table.
    for seed in [0u64, 5] {
        let (p, t) = synthetic_instance(0x400 + seed);
        let uniform = UniformCost::from_problem(&p);
        for devices in [1usize, 3] {
            let cfg = SimConfig { n_devices: devices, ..Default::default() };
            let fleet = DeviceFleet::uniform(devices);
            let blind = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
            let aware = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::device_aware(p)) };
            let aware_tbl =
                |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::with_cost_model(p, &uniform)) };
            let a = simulate_fleet(&p, &t, &fleet, &blind, &cfg);
            let b = simulate_fleet(&p, &t, &fleet, &aware, &cfg);
            let c = simulate_fleet_with_cost_model(
                &p,
                &t,
                &fleet,
                &aware_tbl,
                &cfg,
                Some(&uniform as &dyn CostModel),
            );
            assert_eq!(sim_key(&a.sim), sim_key(&b.sim), "seed {seed} @M{devices}: no-model run");
            assert_eq!(sim_key(&a.sim), sim_key(&c.sim), "seed {seed} @M{devices}: UniformCost run");
            assert_eq!(fleet_report("degen", &a.sim), fleet_report("degen", &b.sim));
            assert_eq!(fleet_report("degen", &a.sim), fleet_report("degen", &c.sim));
        }
    }
}

#[test]
fn device_aware_inplace_hooks_match_force_rebuild_oracle_under_churn() {
    // Same invariant as `inplace_device_hooks_match_force_rebuild_oracle`
    // but under `ScoreMode::DeviceRate` with a two-class cost table: the
    // hooks' per-device score invalidation must be indistinguishable
    // from rebuilding the policy from scratch at every fleet event.
    let cfg = FleetConfig {
        n_devices: 3,
        initial_online: 2,
        uptime: (4.0, 10.0),
        outage: (2.0, 5.0),
        horizon: 50.0,
        ..Default::default()
    };
    for seed in 0..4u64 {
        let (p, t) = synthetic_instance(0x500 + seed);
        let fleet =
            fleet_schedule(&cfg, 200 + seed).with_classes(round_robin_classes(cfg.n_devices, 2));
        let model = PerClassCost::from_problem(&p, vec![1.0, 2.0], vec![f64::INFINITY; 2]);
        let m = Some(&model as &dyn CostModel);
        let inc = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::with_cost_model(p, &model)) };
        let oracle = |p: &Problem| -> Box<dyn Policy> {
            Box::new(ForceRebuild(MmGpEi::with_cost_model(p, &model)))
        };
        let a = simulate_fleet_with_cost_model(&p, &t, &fleet, &inc, &SimConfig::default(), m);
        let b = simulate_fleet_with_cost_model(&p, &t, &fleet, &oracle, &SimConfig::default(), m);
        assert_eq!(a.n_rebuilds, 0, "device-aware in-place path never rebuilds");
        assert!(
            b.n_rebuilds > 0 || fleet.events().iter().all(|e| e.time == 0.0),
            "oracle must exercise the rebuild path (seed {seed})"
        );
        assert_eq!(sim_key(&a.sim), sim_key(&b.sim), "seed {seed}: schedules diverged");
        assert_eq!(a.sim.cumulative_regret.to_bits(), b.sim.cumulative_regret.to_bits());
        assert_eq!(a.sim.inst_regret, b.sim.inst_regret);
        assert_eq!(a.n_preemptions, b.n_preemptions);
    }
}

// ---------------------------------------------------------------------
// 5. Fault-trace parity: mock-clock fleet adapter vs fault simulator.
// ---------------------------------------------------------------------

/// Fold a faulty run's deterministic quantities into a smoke report so
/// two runs serialize byte-identically iff they agree float for float.
fn faults_report(
    name: &str,
    r: &SimResult,
    stats: &FaultStats,
    served_fraction: f64,
) -> String {
    let mut rep = RunReport::new(name, 0, true);
    rep.push_kpi("cumulative_regret", r.cumulative_regret, Direction::LowerIsBetter);
    rep.push_kpi("final_regret", r.inst_regret.final_value(), Direction::LowerIsBetter);
    rep.push_kpi("makespan", r.makespan, Direction::LowerIsBetter);
    rep.push_kpi("served_fraction", served_fraction, Direction::HigherIsBetter);
    rep.push_kpi("crashes", stats.n_crashes as f64, Direction::LowerIsBetter);
    rep.push_kpi("job_failures", stats.n_job_failures as f64, Direction::LowerIsBetter);
    rep.push_kpi("deadline_kills", stats.n_deadline_kills as f64, Direction::LowerIsBetter);
    rep.push_kpi("stragglers", stats.n_stragglers as f64, Direction::LowerIsBetter);
    rep.push_kpi("retries", stats.n_retries as f64, Direction::LowerIsBetter);
    rep.push_kpi("abandoned", stats.n_abandoned as f64, Direction::LowerIsBetter);
    for (i, &l) in stats.recovery_latency.iter().enumerate() {
        rep.push_kpi(format!("recovery_latency/{i}"), l, Direction::LowerIsBetter);
    }
    rep.to_json_string()
}

#[test]
fn wall_fleet_adapter_replays_fault_simulator_bitwise() {
    // A handcrafted preemption-heavy trace on an elastic fleet: crash
    // and restart cycles overlapping the fleet's own availability churn,
    // a lost job, a straggler slow enough to blow its stretched
    // deadline, and a tight retry budget so every fault path fires.
    let (p, t) = synthetic_instance(0x600);
    let fleet = fleet_schedule(
        &FleetConfig {
            n_devices: 3,
            initial_online: 3,
            uptime: (10.0, 25.0),
            outage: (2.0, 6.0),
            horizon: 60.0,
            ..Default::default()
        },
        11,
    );
    let plan = FaultPlan::new(
        3,
        vec![
            FaultEvent { time: 0.4, device: 0, kind: FaultKind::DeviceCrash },
            FaultEvent { time: 0.6, device: 1, kind: FaultKind::JobFailure },
            FaultEvent { time: 1.1, device: 2, kind: FaultKind::Straggler(4.0) },
            FaultEvent { time: 2.2, device: 0, kind: FaultKind::DeviceRestart },
            FaultEvent { time: 3.0, device: 1, kind: FaultKind::DeviceCrash },
            FaultEvent { time: 4.5, device: 1, kind: FaultKind::DeviceRestart },
            FaultEvent { time: 5.0, device: 2, kind: FaultKind::JobFailure },
        ],
        RetryPolicy { deadline_factor: 3.0, max_retries: 2, ..RetryPolicy::default() },
    );
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let sim_cfg = SimConfig {
        n_devices: fleet.n_devices(),
        warm_start_per_user: 2,
        horizon: None,
        stop_at_cutoff: None,
    };
    let v = simulate_faults(&p, &t, &fleet, &plan, &factory, &sim_cfg);
    let w = serve_fleet_deterministic(
        &p,
        &t,
        &fleet,
        Some(&plan),
        &factory,
        &ServeConfig {
            n_devices: fleet.n_devices(),
            time_scale: 1.0,
            warm_start_per_user: 2,
            verbose: false,
        },
    );
    // The trace must actually exercise the fault machinery.
    assert!(v.fault_stats.n_crashes >= 1);
    assert!(v.fault_stats.n_job_failures >= 1);
    assert!(v.fleet.n_preemptions >= 1, "crashes must preempt in-flight work");

    // Schedules: same arms on the same devices at the same instants
    // (through the same Duration conversion both report types use).
    let v_key: Vec<(usize, usize, Duration, Duration)> = v
        .fleet
        .sim
        .observations
        .iter()
        .map(|o| {
            (
                o.arm,
                o.device,
                Duration::from_secs_f64(o.start.max(0.0)),
                Duration::from_secs_f64(o.finish.max(0.0)),
            )
        })
        .collect();
    let w_key: Vec<(usize, usize, Duration, Duration)> =
        w.jobs.iter().map(|j| (j.arm, j.device, j.start, j.finish)).collect();
    assert_eq!(v_key, w_key, "wall and virtual adapters must replay one faulty schedule");

    // Regret floats, fault counters, preemption accounting.
    assert_eq!(v.fleet.sim.inst_regret, w.inst_regret, "regret curves must be identical");
    assert_eq!(v.fleet.n_preemptions, w.n_preemptions);
    assert_eq!(v.fleet.n_rebuilds, w.n_rebuilds);
    assert_eq!(v.fault_stats, w.fault_stats);
    assert_eq!(v.served_fraction.to_bits(), w.served_fraction.to_bits());

    // Report bytes. The wall report stores its makespan
    // nanosecond-quantized, so both sides go through the same Duration
    // conversion before serializing (same convention as the churn
    // cross-loop gate above).
    assert_eq!(
        Duration::from_secs_f64(v.fleet.sim.makespan.max(0.0)),
        w.makespan,
        "makespans must agree through the Duration conversion"
    );
    let mut v_sim = v.fleet.sim.clone();
    v_sim.makespan = Duration::from_secs_f64(v.fleet.sim.makespan.max(0.0)).as_secs_f64();
    let mut w_sim = v.fleet.sim.clone();
    w_sim.makespan = w.makespan.as_secs_f64();
    let v_report = faults_report("fault-parity", &v_sim, &v.fault_stats, v.served_fraction);
    let w_report = faults_report("fault-parity", &w_sim, &w.fault_stats, w.served_fraction);
    assert_eq!(v_report, w_report, "fault-trace report bytes must be identical");
}

#[test]
fn generated_fault_plan_parity_across_loops() {
    // Same cross-loop invariant under the seeded generator (the fig8
    // bench gates this per-seed; this is the always-on in-repo version).
    let (p, t) = synthetic_instance(0x601);
    let fleet = fleet_schedule(
        &FleetConfig { n_devices: 4, initial_online: 3, horizon: 60.0, ..Default::default() },
        13,
    );
    let plan = fault_plan(
        &FaultsConfig {
            mtbf: 10.0,
            mean_downtime: 3.0,
            job_failure_gap: 6.0,
            straggler_gap: 9.0,
            horizon: 60.0,
            ..Default::default()
        },
        fleet.n_devices(),
        42,
    );
    assert!(!plan.is_empty(), "the aggressive generator preset must produce events");
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let sim_cfg = SimConfig {
        n_devices: fleet.n_devices(),
        warm_start_per_user: 2,
        horizon: None,
        stop_at_cutoff: None,
    };
    let v = simulate_faults(&p, &t, &fleet, &plan, &factory, &sim_cfg);
    let w = serve_fleet_deterministic(
        &p,
        &t,
        &fleet,
        Some(&plan),
        &factory,
        &ServeConfig {
            n_devices: fleet.n_devices(),
            time_scale: 1.0,
            warm_start_per_user: 2,
            verbose: false,
        },
    );
    let v_key: Vec<(usize, usize)> =
        v.fleet.sim.observations.iter().map(|o| (o.arm, o.device)).collect();
    let w_key: Vec<(usize, usize)> = w.jobs.iter().map(|j| (j.arm, j.device)).collect();
    assert_eq!(v_key, w_key);
    assert_eq!(v.fleet.sim.inst_regret, w.inst_regret);
    assert_eq!(v.fault_stats, w.fault_stats);
}

#[test]
fn handcrafted_outage_window_blocks_service() {
    // One device, one outage window [2, 5): nothing can complete inside
    // it, and the in-flight job at t = 2 is preempted and re-served.
    let user_arms = vec![vec![0, 1, 2]];
    let arm_users = Problem::compute_arm_users(3, &user_arms);
    let p = Problem {
        name: "outage".into(),
        n_users: 1,
        cost: vec![1.0, 1.5, 2.0],
        user_arms,
        arm_users,
        prior_mean: vec![0.5; 3],
        prior_cov: mmgpei::linalg::Mat::eye(3),
    };
    let t = mmgpei::problem::Truth { z: vec![0.4, 0.9, 0.6] };
    let fleet = DeviceFleet::new(
        vec![1.0],
        vec![true],
        vec![
            FleetEvent { time: 2.0, device: 0, kind: FleetEventKind::Leave },
            FleetEvent { time: 5.0, device: 0, kind: FleetEventKind::Join },
        ],
    );
    let factory = |p: &Problem| -> Box<dyn Policy> { Box::new(MmGpEi::new(p)) };
    let r = simulate_fleet(&p, &t, &fleet, &factory, &SimConfig::default());
    // Warm start runs arms 0 (c=1, finishes at 1) then 1 (c=1.5, would
    // finish at 2.5 → preempted at 2, re-dispatched at 5).
    assert_eq!(r.n_preemptions, 1);
    assert_eq!(r.requeue_latency.len(), 1);
    assert!((r.requeue_latency[0] - 3.0).abs() < 1e-9, "requeued at the rejoin");
    let mut arms: Vec<_> = r.sim.observations.iter().map(|o| o.arm).collect();
    arms.sort_unstable();
    assert_eq!(arms, vec![0, 1, 2], "every arm is eventually served");
    for o in &r.sim.observations {
        let inside_outage = o.finish > 2.0 + 1e-12 && o.finish < 5.0 - 1e-12;
        assert!(!inside_outage, "arm {} completed during the outage", o.arm);
        assert!(
            !(o.start > 2.0 - 1e-12 && o.start < 5.0 - 1e-12),
            "arm {} dispatched during the outage",
            o.arm
        );
    }
}
