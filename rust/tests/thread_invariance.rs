//! Thread-count invariance: the worker pool's determinism contract.
//!
//! Everything the pool shards — seed sweeps in `cli::run_experiment`,
//! per-user GP updates and EI rescoring inside the independent-GP
//! policies — must produce **byte-identical** results at any thread
//! count. These tests run the same workloads at width 1 and width 4 and
//! compare down to the bit level (serialized report bytes, `f64` bit
//! patterns). CI enforces the same contract end-to-end by `cmp`-ing the
//! whole figure suite's smoke reports at `MMGPEI_THREADS=1` vs `=4`.

use mmgpei::config::ExperimentConfig;
use mmgpei::pool::WorkerPool;
use mmgpei::report::RunReport;
use mmgpei::sched::{GpEiRandom, GpEiRoundRobin, GpUcbRoundRobin, MmGpEiIndep, Policy};
use mmgpei::sim::{simulate, SimConfig, SimResult};
use mmgpei::workload::{synthetic_gp, SyntheticConfig};

/// Bit-level fingerprint of everything a simulation result feeds into
/// reports: schedule, revealed values, and regret accounting.
fn sim_key(r: &SimResult) -> (Vec<(usize, usize, u64, u64)>, u64, u64) {
    (
        r.observations.iter().map(|o| (o.arm, o.device, o.start.to_bits(), o.finish.to_bits())).collect(),
        r.cumulative_regret.to_bits(),
        r.makespan.to_bits(),
    )
}

#[test]
fn experiment_report_bytes_are_identical_across_thread_counts() {
    // The figure-suite smoke path in miniature: a multi-policy sweep on
    // the synthetic workload, serialized through the same RunReport
    // machinery the bench binaries emit. Width 1 vs width 4 must agree
    // byte for byte.
    let run = |threads: usize| -> String {
        let cfg = ExperimentConfig {
            name: "thread-invariance".into(),
            dataset: "synthetic".into(),
            policies: vec!["mdmt".into(), "mdmt-indep".into(), "round-robin".into(), "random".into()],
            devices: vec![1, 2],
            seeds: 3,
            threads,
            synthetic: SyntheticConfig { n_users: 6, n_models: 5, ..Default::default() },
            ..Default::default()
        };
        let res = mmgpei::cli::run_experiment(&cfg).expect("sweep");
        let mut report = RunReport::new("thread_invariance", 0, true);
        report.provenance.commit = "pinned".into(); // not thread-related
        res.push_kpis(&mut report, "syn/", &[0.05, 0.01]);
        report.to_json_string()
    };
    let serial = run(1);
    let pooled = run(4);
    assert_eq!(serial, pooled, "pooled seed sweep must serialize byte-identically");
    assert!(serial.contains("cumulative_regret"), "report must actually carry KPIs");
}

/// Run the same simulation with a width-1 and a width-4 policy and
/// assert bit-identical results.
fn assert_width_invariant<P: Policy>(
    name: &str,
    problem: &mmgpei::problem::Problem,
    truth: &mmgpei::problem::Truth,
    sim_cfg: &SimConfig,
    make: impl Fn(WorkerPool) -> P,
) {
    let serial = {
        let mut pol = make(WorkerPool::new(1));
        simulate(problem, truth, &mut pol, sim_cfg)
    };
    let pooled = {
        let mut pol = make(WorkerPool::new(4));
        simulate(problem, truth, &mut pol, sim_cfg)
    };
    assert_eq!(sim_key(&serial), sim_key(&pooled), "{name}: width 4 must replay width 1 exactly");
}

#[test]
fn sharded_policies_replay_serial_runs_bit_for_bit() {
    // Policy-internal sharding (per-user GP observes, indep EI
    // rescoring): the same simulation driven by a width-1 and a width-4
    // policy must produce identical schedules and identical regret bits.
    // 36 tenants clears pool::FINE_SHARD_MIN_ITEMS, so the width-4 run
    // genuinely exercises the threaded shard paths.
    let cfg = SyntheticConfig { n_users: 36, n_models: 4, ..Default::default() };
    let (problem, truth) = synthetic_gp(&cfg, 0x7123AD);
    let sim_cfg = SimConfig { n_devices: 3, ..Default::default() };
    assert_width_invariant("round-robin", &problem, &truth, &sim_cfg, |pool| {
        GpEiRoundRobin::with_pool(&problem, pool)
    });
    assert_width_invariant("random", &problem, &truth, &sim_cfg, |pool| {
        GpEiRandom::with_pool(&problem, 77, pool)
    });
    assert_width_invariant("indep", &problem, &truth, &sim_cfg, |pool| {
        MmGpEiIndep::with_pool(&problem, pool)
    });
    assert_width_invariant("ucb-rr", &problem, &truth, &sim_cfg, |pool| {
        GpUcbRoundRobin::with_pool(&problem, pool)
    });
}

#[test]
fn shared_arm_fanout_is_width_invariant() {
    // Shared arms make several user GPs update on one completion — the
    // case where per-user sharding actually fans out. Still bit-stable.
    // (36 tenants: above the fine-shard threshold, threads engage.)
    let cfg = SyntheticConfig { n_users: 36, n_models: 4, ..Default::default() };
    let (mut problem, truth) = synthetic_gp(&cfg, 0x5AAE);
    // Give every user a stake in arm 0.
    for u in 1..problem.n_users {
        if !problem.user_arms[u].contains(&0) {
            problem.user_arms[u].push(0);
        }
    }
    problem.arm_users = mmgpei::problem::Problem::compute_arm_users(problem.n_arms(), &problem.user_arms);
    problem.validate();
    let sim_cfg = SimConfig { n_devices: 2, ..Default::default() };
    let serial = {
        let mut pol = MmGpEiIndep::with_pool(&problem, WorkerPool::new(1));
        simulate(&problem, &truth, &mut pol, &sim_cfg)
    };
    let pooled = {
        let mut pol = MmGpEiIndep::with_pool(&problem, WorkerPool::new(4));
        simulate(&problem, &truth, &mut pol, &sim_cfg)
    };
    assert_eq!(sim_key(&serial), sim_key(&pooled));
}
